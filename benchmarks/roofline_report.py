"""Render the roofline table from dry-run jsonl output, optionally with
measured transport bytes from a telemetry JSONL stream next to the
modeled collective terms.

  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun.jsonl \
      [--telemetry obs.jsonl]
"""
from __future__ import annotations

import argparse
import json

from repro.launch.roofline import measured_wire_bytes


def load(path):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_row(r):
    dom = r["dominant"].replace("_s", "")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {dom} "
            f"| {r['useful_ratio']:.2f} |")


def _mb(x):
    return f"{x / 1e6:.3f} MB"


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m benchmarks.roofline_report")
    ap.add_argument("path", nargs="?", default="results/dryrun.jsonl")
    ap.add_argument("--telemetry", default=None,
                    help="telemetry JSONL: print MEASURED wire bytes "
                         "(obs wire/* gauges) next to the modeled terms")
    args = ap.parse_args(argv)
    rows = load(args.path)
    print("| arch | shape | mesh | compute s | memory s | collective s "
          "| bound | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    seen = set()
    coll_bytes = []
    for r in rows:
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        print(fmt_row(r))
        if "collective_bytes" in r:
            coll_bytes.append(float(r["collective_bytes"]))
    if args.telemetry:
        w = measured_wire_bytes(args.telemetry)
        print()
        print("## measured transport (telemetry wire/* gauges)")
        if w["rounds"] == 0:
            print("no wire gauges in the stream (telemetry counters off?)")
        else:
            print(f"rounds: {w['rounds']}")
            print(f"uplink:   {_mb(w['bytes_up'])} total, "
                  f"{_mb(w['bytes_up_per_round'])}/round")
            print(f"downlink: {_mb(w['bytes_down'])} total, "
                  f"{_mb(w['bytes_down_per_round'])}/round")
            if coll_bytes:
                mean_coll = sum(coll_bytes) / len(coll_bytes)
                print(f"modeled collective bytes (mean over table rows): "
                      f"{_mb(mean_coll)}")


if __name__ == "__main__":
    main()
