"""Render the roofline table from dry-run jsonl output.

  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path):
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_row(r):
    dom = r["dominant"].replace("_s", "")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {dom} "
            f"| {r['useful_ratio']:.2f} |")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl"
    rows = load(path)
    print("| arch | shape | mesh | compute s | memory s | collective s "
          "| bound | MODEL/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    seen = set()
    for r in rows:
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:
            continue
        seen.add(key)
        print(fmt_row(r))


if __name__ == "__main__":
    main()
