"""Robustness regression matrix: runs the scenario registry (attack x
heterogeneity x compression x aggregator cells, repro/scenarios/) through
the SimEngine and merges one ``robustness/<cell>`` row per scenario into
BENCH_kernels.json next to the kernel-perf trajectory.

  PYTHONPATH=src python -m benchmarks.bench_scenarios [--grid] [--only X]

Budget small runs the curated cells at smoke sizes; ``--grid`` (the CI
scenario-matrix job) runs the generated {gate_aware, alie, none} x
{trimmed_mean, krum, fedavg} x {dropout on/off} smoke grid instead.
Rows merge through ``common.merge_rows`` (replace same-name rows,
preserve everything else), like every other bench.
"""
from __future__ import annotations

import argparse

from benchmarks import common
from benchmarks.common import merge_rows      # back-compat re-export
from repro.scenarios import SCENARIOS, run_scenario, smoke_grid

SIZES = {
    "small": dict(n_rounds=6, n=800),
    "full": dict(n_rounds=12, n=1600),
}


def run_cells(cells, *, n_rounds, n, seed=0):
    rows = []
    for name in cells:
        summary, _ = run_scenario(name, n_rounds=n_rounds, n=n, seed=seed)
        rows.append(summary)
        common.csv_row(
            summary["name"], summary["wall_s"],
            f"final_acc={summary['final_acc']:.3f} "
            f"best={summary['best_acc']:.3f} "
            f"trig={summary['final_trigger_acc']:.3f} "
            f"gini={summary['fair_part_gini']:.2f}")
    return rows


def main(budget="small", grid=False, only=None):
    cells = smoke_grid() if grid else SCENARIOS
    names = [c for c in cells if only is None or only in c]
    rows = run_cells(names, **SIZES[budget])
    merged = merge_rows(rows)
    print(f"# wrote {common.bench_json_path()} ({len(rows)} robustness "
          f"rows, {len(merged)} total)", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small", choices=["small", "full"])
    ap.add_argument("--grid", action="store_true",
                    help="run the CI smoke grid instead of the curated "
                         "scenario cells")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    main(budget=args.budget, grid=args.grid, only=args.only)
