"""Kernel micro-benchmarks: wall time per call (CPU interpret / XLA-ref
execution — TPU numbers come from the dry-run roofline) + analytic kernel
roofline (FLOPs, bytes, arithmetic intensity per VMEM tile)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs.base import HBM_BW, PEAK_FLOPS_BF16
from repro.kernels.flash_attention_ops import flash_attention
from repro.kernels.robust_agg_ops import robust_aggregate_tree


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)[0].block_until_ready() if isinstance(fn(*args, **kw),
                                                         tuple) else \
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.time() - t0) / reps


def flash_roofline(B, S, Hq, dh, window, blk=128):
    """Analytic per-chip roofline for the flash kernel."""
    kv_touched = min(window or S, S)
    flops = 4.0 * B * Hq * S * kv_touched * dh         # qk^T + pv
    byts = 2.0 * B * S * Hq * dh * 2 + 2.0 * B * kv_touched * Hq * dh * 2
    return {
        "flops": flops, "bytes": byts,
        "intensity": flops / byts,
        "t_compute_us": 1e6 * flops / PEAK_FLOPS_BF16,
        "t_memory_us": 1e6 * byts / HBM_BW,
        "vmem_tile_kb": (3 * blk * dh * 2 + blk * dh * 4) / 1024,
    }


def run(budget="small"):
    out = []
    B, S, Hq, Hkv, dh = 1, 256, 4, 2, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, Hq, dh), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hkv, dh), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hkv, dh), jnp.bfloat16)
    for window in [0, 128]:
        t = _time(lambda: flash_attention(q, k, v, window=window,
                                          interpret=True))
        r = {"name": f"flash_attention/S{S}/w{window}", "wall_s": t}
        r.update(flash_roofline(B, S, Hq, dh, window))
        out.append(r)
    # long-context projection (the long_500k serving tile)
    out.append({"name": "flash_attention/S524288/w8192(analytic)",
                "wall_s": 0.0,
                **flash_roofline(1, 524288, 64, 128, 8192)})

    C = 16
    tree = {"w": jax.random.normal(key, (C, 1 << 14))}
    mask = jnp.ones((C,))
    for mode in ["trimmed", "median"]:
        t = _time(lambda: robust_aggregate_tree(tree, mask, mode=mode,
                                                interpret=True))
        n = tree["w"].size
        out.append({"name": f"robust_agg/{mode}/C{C}/N{n}", "wall_s": t,
                    "flops": 3.0 * C * C * n / C,
                    "bytes": 4.0 * n * (C + 1) / C})
    return out


def main():
    for r in run():
        extra = f"intensity={r.get('intensity', 0):.1f}" \
            if "intensity" in r else ""
        common.csv_row(r["name"], r["wall_s"], extra)


if __name__ == "__main__":
    main()
