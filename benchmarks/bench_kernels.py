"""Kernel micro-benchmarks: wall time per call (CPU interpret / XLA-ref
execution — TPU numbers come from the dry-run roofline) + analytic kernel
roofline (FLOPs, bytes, arithmetic intensity per VMEM tile).

``robust_pipeline`` compares the fused two-pass Pallas Eq.-11 engine
(kernels/robust_pipeline.py) against the multi-pass XLA reference
(aggregation.aggregate_ref) and accounts HBM passes analytically.
``robust_pipeline/leafwise`` times the segment-table leaf-streaming
engine against the PR-1 flatten path on a multi-leaf tree, and
``robust_pipeline/sharded`` the shard_map'd per-client path against the
replicated one on however many devices exist (the CI multi-device job
forces 4 host devices), recording the parity gap vs the XLA oracle.
``comm/*`` records the compressed-transport subsystem (repro/comm):
per-codec encode+decode wall and measured bytes-on-wire per round vs the
dense uplink, and the int8 fused dequant-into-aggregation kernels vs the
dense fused engine (agg-byte reduction ~4x at qblk=128).
``population_select/*`` records the O(M) Gumbel-top-d cohort-selection
engines (kernels/population_select.py) against the dense argsort
baseline at M up to 1e6 registered clients.
Results are also dumped to BENCH_kernels.json (the perf trajectory
artifact CI uploads every run).
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs.base import HBM_BW, PEAK_FLOPS_BF16, FedConfig
from repro.core import aggregation
from repro.kernels import population_select
from repro.kernels.flash_attention_ops import flash_attention
from repro.kernels.robust_agg_ops import robust_aggregate_tree
from repro.kernels.robust_pipeline import (fused_aggregate_tree,
                                           fused_aggregate_tree_flat)

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")


def _time(fn, *args, reps=5, warmup=1, **kw):
    """Clean warmup + timed-reps helper: runs ``warmup`` untimed calls
    (compile + cache fill), then takes the BEST of ``reps`` individually
    timed calls (min is robust to scheduler noise on shared machines).
    jax.block_until_ready handles any pytree/tuple result."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def flash_roofline(B, S, Hq, dh, window, blk=128):
    """Analytic per-chip roofline for the flash kernel."""
    kv_touched = min(window or S, S)
    flops = 4.0 * B * Hq * S * kv_touched * dh         # qk^T + pv
    byts = 2.0 * B * S * Hq * dh * 2 + 2.0 * B * kv_touched * Hq * dh * 2
    return {
        "flops": flops, "bytes": byts,
        "intensity": flops / byts,
        "t_compute_us": 1e6 * flops / PEAK_FLOPS_BF16,
        "t_memory_us": 1e6 * byts / HBM_BW,
        "vmem_tile_kb": (3 * blk * dh * 2 + blk * dh * 4) / 1024,
    }


def robust_pipeline_roofline(C, N, aggregator):
    """HBM-pass accounting for the Eq.-11 pipeline over the (C, N) f32
    update matrix (one pass = C*N*4 bytes moved).

    Reference (aggregation.aggregate_ref), all sort-based:
      median reference   sort read + sorted write      2 passes
      cosine gate        read                          1 pass
      aggregator         sort read + write + reduce    3 passes
                         (fedavg: 1 read; krum: gram read + mean read = 2)
    Fused (kernels/robust_pipeline.py), streaming:
      pass 1  read (median ref + cosine partials)      1 pass
      pass 2  read (gated combine)                     1 pass
      krum    +1 blocked pairwise-distance read        1 pass

    The leaf-streaming wrappers hit this kernel-contract roofline
    end-to-end (a reshape view per leaf, no copy); the PR-1 flatten path
    adds ~2 passes (concatenate write + re-read) for multi-leaf trees,
    accounted by ``hbm_passes_flatten`` in the leafwise entries.
    """
    ref = {"fedavg": 4.0, "median": 6.0, "trimmed_mean": 6.0, "krum": 5.0}
    fused = {"fedavg": 2.0, "median": 2.0, "trimmed_mean": 2.0, "krum": 3.0}
    bytes_per_pass = 4.0 * C * N
    return {
        "hbm_passes_ref": ref[aggregator],
        "hbm_passes_fused": fused[aggregator],
        "hbm_pass_ratio": ref[aggregator] / fused[aggregator],
        "bytes_ref": ref[aggregator] * bytes_per_pass,
        "bytes_fused": fused[aggregator] * bytes_per_pass,
        # rank network: C^2 compares + C picks per coordinate, 2 sweeps
        "flops_fused": 2.0 * C * C * N + 4.0 * C * N,
        "t_memory_fused_us": 1e6 * fused[aggregator] * bytes_per_pass / HBM_BW,
    }


def run(budget="small"):
    out = []
    B, S, Hq, Hkv, dh = 1, 256, 4, 2, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, Hq, dh), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hkv, dh), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hkv, dh), jnp.bfloat16)
    for window in [0, 128]:
        t = _time(lambda: flash_attention(q, k, v, window=window,
                                          interpret=True))
        r = {"name": f"flash_attention/S{S}/w{window}", "wall_s": t}
        r.update(flash_roofline(B, S, Hq, dh, window))
        out.append(r)
    # long-context projection (the long_500k serving tile)
    out.append({"name": "flash_attention/S524288/w8192(analytic)",
                "wall_s": 0.0,
                **flash_roofline(1, 524288, 64, 128, 8192)})

    C = 16
    tree = {"w": jax.random.normal(key, (C, 1 << 14))}
    mask = jnp.ones((C,))
    for mode in ["trimmed", "median"]:
        t = _time(lambda: robust_aggregate_tree(tree, mask, mode=mode,
                                                interpret=True))
        n = tree["w"].size
        out.append({"name": f"robust_agg/{mode}/C{C}/N{n}", "wall_s": t,
                    "flops": 3.0 * C * C * n / C,
                    "bytes": 4.0 * n * (C + 1) / C})

    # ---- fused Eq.-11 pipeline vs multi-pass XLA reference ----
    C, N = 16, 1 << 16
    ptree = {"w": jax.random.normal(key, (C, N))}
    pmask = jnp.ones((C,)).at[0].set(0.0)
    pw = jnp.ones((C,))
    aggs = ["trimmed_mean", "median"] if budget == "small" else \
        ["fedavg", "trimmed_mean", "median", "krum"]
    for agg in aggs:
        cfg = FedConfig(n_clients=C, aggregator=agg)
        ref_fn = jax.jit(functools.partial(aggregation.aggregate_ref,
                                           cfg=cfg))
        # interleave the contenders so cgroup-throttle bursts on shared
        # CI runners hit both timing windows equally
        t_ref, t_fused = float("inf"), float("inf")
        for _ in range(7):
            t_ref = min(t_ref, _time(lambda: ref_fn(ptree, pw, pmask),
                                     reps=1))
            t_fused = min(t_fused, _time(
                lambda: fused_aggregate_tree(ptree, pw, pmask, cfg,
                                             blk=8192), reps=1))
        r = {"name": f"robust_pipeline/{agg}/C{C}/N{N}", "wall_s": t_fused,
             "wall_s_ref": t_ref, "speedup_vs_ref": t_ref / t_fused}
        r.update(robust_pipeline_roofline(C, N, agg))
        out.append(r)

    # ---- leaf-streaming (segment-table) engine vs the PR-1 flatten path
    # multi-leaf tree totalling N=65536 coords: matrix-shaped leaves plus
    # a ragged one and a tiny bias (the shapes that forced the flatten
    # path's (C, N) concatenate + unflatten copies)
    sizes = [(1 << 14,), (128, 128), (64, 256), (16_000,), (379,), (5,)]
    ltree = {f"l{j}": jax.random.normal(jax.random.fold_in(key, j),
                                       (C,) + s)
             for j, s in enumerate(sizes)}
    n_tot = sum(int(jnp.prod(jnp.asarray(s))) for s in sizes)
    for agg in aggs:
        cfg = FedConfig(n_clients=C, aggregator=agg)
        t_flat, t_leaf = float("inf"), float("inf")
        for _ in range(7):                         # interleaved (see above)
            # flatten baseline runs at blk=4096 — the default the PR-1
            # aggregate() hot path actually shipped with
            t_flat = min(t_flat, _time(
                lambda: fused_aggregate_tree_flat(ltree, pw, pmask, cfg,
                                                  blk=4096), reps=1))
            t_leaf = min(t_leaf, _time(
                lambda: fused_aggregate_tree(ltree, pw, pmask, cfg),
                reps=1))
        r = {"name": f"robust_pipeline/leafwise/{agg}/C{C}/N{n_tot}",
             "wall_s": t_leaf, "wall_s_flatten": t_flat,
             "flatten_blk": 4096,
             "speedup_vs_flatten": t_flat / t_leaf}
        roof = robust_pipeline_roofline(C, n_tot, agg)
        r.update(roof)
        # flatten adds one concatenate write + one re-read of (C, N)
        r["hbm_passes_flatten"] = roof["hbm_passes_fused"] + 2.0
        out.append(r)

    # ---- mesh-sharded per_client path vs replicated, on whatever devices
    # exist (CI forces 4 host CPU devices); parity vs the XLA oracle
    from jax.sharding import Mesh
    import numpy as np
    D = jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(D), ("data",))
    for agg in aggs:
        cfg = FedConfig(n_clients=C, aggregator=agg)
        sh_fn = jax.jit(lambda t, w, m, cfg=cfg: aggregation.aggregate_sharded(
            t, w, m, cfg, mesh, axes=("data",)))
        t_sh, t_rep = float("inf"), float("inf")
        for _ in range(5):
            t_rep = min(t_rep, _time(
                lambda: fused_aggregate_tree(ltree, pw, pmask, cfg),
                reps=1))
            t_sh = min(t_sh, _time(lambda: sh_fn(ltree, pw, pmask), reps=1))
        ref = aggregation.aggregate_ref(ltree, pw, pmask, cfg)
        got = sh_fn(ltree, pw, pmask)
        parity = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32))))
                     for a, b in zip(jax.tree_util.tree_leaves(got),
                                     jax.tree_util.tree_leaves(ref)))
        roof = robust_pipeline_roofline(C, n_tot, agg)
        out.append({
            "name": f"robust_pipeline/sharded/{agg}/C{C}/N{n_tot}/dev{D}",
            "wall_s": t_sh, "wall_s_replicated": t_rep,
            "speedup_vs_replicated": t_rep / t_sh,
            "devices": D, "parity_max_abs_diff": parity,
            "parity_ok_1e-5": bool(parity <= 1e-5),
            # each device streams 1/D of the matrix in both passes
            "bytes_per_device": roof["bytes_fused"] / D,
            "hbm_passes_fused": roof["hbm_passes_fused"],
        })

    # ---- comm codecs: wire bytes + encode/decode wall + fused dequant --
    # same multi-leaf tree as the leafwise section; bytes are MEASURED
    # from the encoded arrays (codes + scales + indices), not modelled
    from repro.comm import codecs as comm_codecs
    from repro.comm.kernels import comm_codecs as dq

    dense_pc = comm_codecs.dense_bytes_per_client(ltree)
    codec_names = ["int8", "topk"] if budget == "small" else \
        ["int8", "int4", "signsgd", "topk"]
    int8_enc, int8_codec = None, None
    for name in codec_names:
        codec = comm_codecs.Codec(name, qblk=128, topk_frac=0.05)
        enc_fn = jax.jit(codec.encode_tree)
        dec_fn = jax.jit(lambda e: codec.decode_tree(e, ltree))
        enc = enc_fn(ltree)
        if name == "int8":
            int8_enc, int8_codec = enc, codec
        t_enc = _time(lambda: enc_fn(ltree))
        t_dec = _time(lambda: dec_fn(enc))
        wire_pc = comm_codecs.wire_bytes_per_client(enc)
        out.append({
            "name": f"comm/{name}/roundtrip/C{C}/N{n_tot}",
            "wall_s": t_enc + t_dec,
            "wall_s_encode": t_enc, "wall_s_decode": t_dec,
            "wire_bytes_per_client": wire_pc,
            "dense_bytes_per_client": dense_pc,
            "wire_reduction": dense_pc / wire_pc,
            # one cohort's uplink per round, on the wire
            "bytes_on_wire_per_round": wire_pc * C,
        })

    # fused dequant-into-aggregation vs the dense fused engine: the
    # aggregation passes stream int8 codes + scales (~C*N*(1 + 4/qblk)
    # bytes/pass) instead of C*N*4 — wall measured, bytes analytic
    for agg in aggs:
        cfg = FedConfig(n_clients=C, aggregator=agg, compress="int8")
        dq_fn = jax.jit(lambda e, w, m, cfg=cfg: dq.fused_dequant_aggregate_tree(
            e, w, m, cfg, like=ltree))
        t_dense, t_dq = float("inf"), float("inf")
        for _ in range(5):                         # interleaved (see above)
            t_dense = min(t_dense, _time(
                lambda: fused_aggregate_tree(ltree, pw, pmask, cfg),
                reps=1))
            t_dq = min(t_dq, _time(lambda: dq_fn(int8_enc, pw, pmask),
                                   reps=1))
        roof = robust_pipeline_roofline(C, n_tot, agg)
        passes = roof["hbm_passes_fused"]
        bytes_dq = passes * C * n_tot * (1.0 + 4.0 / int8_codec.qblk)
        out.append({
            "name": f"comm/fused_dequant/{agg}/C{C}/N{n_tot}",
            "wall_s": t_dq, "wall_s_dense_fused": t_dense,
            "speedup_vs_dense_fused": t_dense / t_dq,
            "hbm_passes_fused": passes,
            "agg_bytes_dense": roof["bytes_fused"],
            "agg_bytes_dequant": bytes_dq,
            "agg_bytes_reduction": roof["bytes_fused"] / bytes_dq,
            "bytes_on_wire_per_round":
                comm_codecs.wire_bytes_per_client(int8_enc) * C,
        })

    # ---- O(M) population selection (kernels/population_select.py) -----
    # Gumbel-top-d cohort sampling for the buffered-async engine: the
    # segmented two-stage reduction and the blocked Pallas kernel
    # (interpret mode off-TPU) vs the dense O(M log M) argsort baseline,
    # at registry sizes up to the million-client regime (d = 64 cohort)
    d_sel = 64
    for m_pop in (10_000, 100_000, 1_000_000):
        g = jax.random.normal(jax.random.fold_in(key, m_pop), (m_pop,))
        walls = {}
        for method in ("argsort", "segmented", "pallas"):
            fn = jax.jit(functools.partial(population_select.topd, d=d_sel,
                                           method=method, blk=4096))
            walls[method] = _time(lambda: fn(g), reps=3)
        for method in ("segmented", "pallas"):
            out.append({
                "name": f"population_select/{method}/M{m_pop}/d{d_sel}",
                "wall_s": walls[method],
                "wall_s_argsort": walls["argsort"],
                "speedup_vs_argsort": walls["argsort"] / walls[method],
                "population": m_pop, "cohort": d_sel, "blk": 4096,
                # stage 1 streams M keys once; stage 2 merges (M/blk)*d
                # candidates — vs the sort's full key + permutation traffic
                "bytes_stream": 4.0 * m_pop,
                "candidates_merged": (m_pop // 4096 + 1) * d_sel,
            })

    out.append(bench_pod_scan_driver())
    return out


def bench_pod_scan_driver(rounds=8, chunk=4):
    """Multi-round PodEngine training through the shared chunked-scan
    driver (core/driver.py, used by pod.run) vs the per-round jitted
    python loop: the scan driver does ONE host sync per chunk instead of
    one per round and donates the carry.  Tiny-lm reduced config so the
    entry stays cheap on the CI CPU; histories are bit-for-bit equal
    (tests/test_driver.py), so this measures pure driver overhead."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import driver as scan_driver, pod
    from repro.launch.train import synthetic_lm_batches
    from repro.models import transformer
    from repro.optim import optimizers

    cfgm = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                    n_kv_heads=2, d_ff=128, vocab_size=128,
                                    head_dim=16)
    C, B, S = 4, 8, 32
    fed = FedConfig(n_clients=C)
    tc = TrainConfig(global_batch=B, seq_len=S, lr=1e-2, warmup_steps=2,
                     total_steps=rounds)
    params = transformer.init_transformer(jax.random.PRNGKey(0), cfgm)
    opt_init, _ = optimizers.make_optimizer(tc)

    def fresh_state():
        # fresh buffers every call: the drivers DONATE the carry, which
        # would otherwise free the shared template params
        p = jax.tree_util.tree_map(jnp.array, params)
        return pod.init_pod_state(p, opt_init, C, fed,
                                  jax.random.PRNGKey(0))

    step = pod.make_train_step(cfgm, fed, tc)
    sampler = synthetic_lm_batches(cfgm, tc, C, 0)
    sample_key = jax.random.PRNGKey(123)        # never aliased into a carry
    batch_fn = lambda t: sampler(jax.random.fold_in(sample_key, t))

    drv = scan_driver.ScanDriver(lambda st, xs: step(st, xs[1]),
                                 chunk_steps=chunk)
    step_jit = jax.jit(step, donate_argnums=(0,))

    def run_scan(st):
        drv.run(st, batch_fn, rounds)

    def run_python(st):
        for t in range(rounds):
            st, m = step_jit(st, dict(batch_fn(t)))
            jax.device_get(m)                   # per-round host sync

    def time_driver(fn, reps=3):
        fn(fresh_state())                       # warmup: compile paths
        best = float("inf")
        for _ in range(reps):
            st = fresh_state()                  # donated: fresh per rep
            t0 = time.perf_counter()
            fn(st)
            best = min(best, time.perf_counter() - t0)
        return best

    t_scan, t_py = float("inf"), float("inf")
    for _ in range(3):                          # interleaved (see above)
        t_py = min(t_py, time_driver(run_python, reps=1))
        t_scan = min(t_scan, time_driver(run_scan, reps=1))
    return {
        "name": f"driver/pod_scan/R{rounds}/chunk{chunk}/C{C}",
        "wall_s": t_scan, "wall_s_python": t_py,
        "speedup_vs_python": t_py / t_scan,
        "rounds": rounds, "chunk_rounds": chunk,
        "host_syncs_scan": -(-rounds // chunk), "host_syncs_python": rounds,
    }


def main(budget="small"):
    results = run(budget)
    for r in results:
        if "speedup_vs_flatten" in r:
            extra = (f"speedup_vs_flatten={r['speedup_vs_flatten']:.2f}x "
                     f"hbm_passes={r['hbm_passes_fused']:.0f}"
                     f"/{r['hbm_passes_flatten']:.0f}")
        elif "speedup_vs_replicated" in r:
            extra = (f"speedup_vs_replicated="
                     f"{r['speedup_vs_replicated']:.2f}x dev={r['devices']} "
                     f"parity={r['parity_max_abs_diff']:.1e}")
        elif "speedup_vs_dense_fused" in r:
            extra = (f"speedup_vs_dense_fused="
                     f"{r['speedup_vs_dense_fused']:.2f}x "
                     f"agg_bytes_x{r['agg_bytes_reduction']:.1f}")
        elif "wire_reduction" in r:
            extra = (f"wire_x{r['wire_reduction']:.1f} "
                     f"bytes/round={r['bytes_on_wire_per_round']:.0f}")
        elif "speedup_vs_argsort" in r:
            extra = (f"speedup_vs_argsort={r['speedup_vs_argsort']:.1f}x "
                     f"M={r['population']} d={r['cohort']}")
        elif "speedup_vs_python" in r:
            extra = (f"speedup_vs_python={r['speedup_vs_python']:.2f}x "
                     f"syncs={r['host_syncs_scan']}"
                     f"/{r['host_syncs_python']}")
        elif "speedup_vs_ref" in r:
            extra = (f"speedup={r['speedup_vs_ref']:.2f}x "
                     f"hbm_passes={r['hbm_passes_fused']:.0f}"
                     f"/{r['hbm_passes_ref']:.0f}")
        elif "intensity" in r:
            extra = f"intensity={r['intensity']:.1f}"
        else:
            extra = ""
        common.csv_row(r["name"], r["wall_s"], extra)
    # non-destructive merge by row name: other benches' sections
    # (robustness/* rows, driver rows from separate runs) survive no
    # matter where this bench sits in benchmarks/run.py
    merged = common.merge_rows(results, path=BENCH_JSON)
    print(f"# wrote {BENCH_JSON} ({len(results)} kernel rows, "
          f"{len(merged)} total)", flush=True)


if __name__ == "__main__":
    main()
