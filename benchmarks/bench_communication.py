"""Paper SSIV communication-complexity claim: per-round and total
uplink/downlink vs FedAvg/FedRand/FedPow.

Model: each billed client-round moves 2*|params| (down: global model,
up: update). FedFiTS bills all clients on FFA rounds and only the team on
slot rounds; round-based baselines bill their per-round selection."""
from __future__ import annotations

import jax

from benchmarks import common


def run(budget="small"):
    K = 16
    rounds = 10 if budget == "small" else 30
    model, fed, ev = common.make_setup("images", n_clients=K, n=2400)
    params = model.init(jax.random.PRNGKey(0))
    p_bytes = sum(l.size * 4 for l in jax.tree_util.tree_leaves(params))
    out = []
    for algo, kw in [("fedavg", {}), ("fedrand", {"fedrand_c": 0.5}),
                     ("fedpow", {"fedpow_m": 8}), ("fedfits", {})]:
        r = common.run_fl(model, fed, ev, algo=algo, rounds=rounds,
                          n_clients=K, **kw)
        r.pop("state")
        cr = r["cost_client_rounds"]
        r.update({
            "param_bytes": p_bytes,
            "total_comm_mb": round(2 * cr * p_bytes / 1e6, 1),
            "comm_per_round_mb": round(2 * cr * p_bytes / rounds / 1e6, 2),
        })
        out.append(r)
    return out


def main():
    for r in run():
        common.csv_row(f"comm/{r['algo']}", r["wall_s"],
                       f"total_mb={r['total_comm_mb']};best_acc={r['best_acc']:.3f}")


if __name__ == "__main__":
    main()
