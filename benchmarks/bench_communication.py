"""Paper SSIV communication-complexity claim: per-round and total
uplink/downlink vs FedAvg/FedRand/FedPow, in BOTH accountings:

  analytic   the paper's model — each billed client-round moves
             2*|params| bytes (down: global model, up: update), with
             |params| from the ACTUAL leaf dtype itemsizes (a bf16 leaf
             is 2 bytes, not a flat 4);
  measured   the transport subsystem's `cost_bytes_up/down` (repro/comm):
             the uplink bills the ENCODED wire sizes (codes + scales +
             indices), the downlink the dense model broadcast.

The codec sweep at the bottom quantifies the uplink cut of each wire
format at unchanged client-round cost (FedFiTS selection is driven by
client-side fitness metrics, which compression does not touch).
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.comm import codecs


def run(budget="small"):
    K = 16
    rounds = 10 if budget == "small" else 30
    model, fed, ev = common.make_setup("images", n_clients=K, n=2400)
    params = model.init(jax.random.PRNGKey(0))
    # the same itemsize accounting the measured columns are billed with
    p_bytes = codecs.param_bytes(params)
    out = []
    for algo, kw in [("fedavg", {}), ("fedrand", {"fedrand_c": 0.5}),
                     ("fedpow", {"fedpow_m": 8}), ("fedfits", {})]:
        r = common.run_fl(model, fed, ev, algo=algo, rounds=rounds,
                          n_clients=K, **kw)
        r.pop("state")
        cr = r["cost_client_rounds"]
        r.update({
            "param_bytes": p_bytes,
            "analytic_total_mb": round(2 * cr * p_bytes / 1e6, 1),
            "analytic_per_round_mb": round(2 * cr * p_bytes / rounds / 1e6,
                                           2),
            # measured accounting, billed from the actual wire sizes
            "measured_up_mb": round(r["cost_bytes_up"] / 1e6, 2),
            "measured_down_mb": round(r["cost_bytes_down"] / 1e6, 2),
        })
        out.append(r)

    # ---- codec sweep: measured uplink bytes per wire format ------------
    codecs = ["none", "int8", "topk"] if budget == "small" else \
        ["none", "int8", "int4", "signsgd", "topk"]
    dense_up = None
    for comp in codecs:
        r = common.run_fl(model, fed, ev, algo="fedfits", rounds=rounds,
                          n_clients=K, aggregator="trimmed_mean",
                          compress=comp, compress_topk_frac=0.1)
        r.pop("state")
        if comp == "none":
            dense_up = r["cost_bytes_up"]
        r.update({
            "measured_up_mb": round(r["cost_bytes_up"] / 1e6, 2),
            "measured_down_mb": round(r["cost_bytes_down"] / 1e6, 2),
            "uplink_reduction": round(dense_up / max(r["cost_bytes_up"], 1),
                                      2),
        })
        r["algo"] = f"fedfits+{comp}"
        out.append(r)
    return out


def main():
    for r in run():
        extra = (f"analytic_mb={r['analytic_total_mb']}"
                 if "analytic_total_mb" in r else
                 f"up_x{r['uplink_reduction']}")
        common.csv_row(
            f"comm/{r['algo']}", r["wall_s"],
            f"up_mb={r['measured_up_mb']};down_mb={r['measured_down_mb']};"
            f"{extra};cost={r['cost_client_rounds']:.0f};"
            f"best_acc={r['best_acc']:.3f}")


if __name__ == "__main__":
    main()
