"""Serving-engine benchmarks (repro/serve): the tokens/s table for the
continuous-batching tentpole.

``serve/continuous_vs_fixed`` runs the SAME compiled admit/decode
programs under both schedulers on a mixed-length workload (generation
budgets in [16, 256]: mostly short turns, one long generation per
``max_slots`` arrivals) at equal max batch — scheduling is the only
variable, and the acceptance bar is >= 2x tokens/s for continuous.

``serve/decode_{dense,paged,paged_int8}`` times one decode step of each
cache regime at the same batch width and records the KV bytes it
streams: dense reads the full ``max_len`` cache for every slot; paged
reads only live pages (measured from the engine's ``serve/pages_in_use``
gauge); int8 pages cut the per-row payload ~3.8x (1-byte codes + f32
per-row scale vs 4-byte values).

Rows merge into BENCH_kernels.json via common.merge_rows (section key
``serve/``); the scheduler comparison uses the XLA reference attention
so the CPU row times the scheduler, not the interpreter.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.configs.registry import get_config
from repro.launch.serve import draw_requests, make_decode_step
from repro.models.model import build
from repro.serve import ServeConfig, ServeEngine, kv_bytes_read


def _time_threaded(step, state, reps=5, warmup=2):
    """Best-of-reps for a state-threading step fn (donation-safe: the
    carry is rebound every call instead of reusing donated buffers)."""
    for _ in range(warmup):
        state = step(state)
        jax.block_until_ready(state)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        state = step(state)
        jax.block_until_ready(state)
        best = min(best, time.perf_counter() - t0)
    return best, state


def _warm_engine(cfg, scfg, params, *, steps=24, seed=0):
    """An engine mid-flight: every slot admitted and decoded ``steps``
    in, so the timed step sees realistic page occupancy."""
    engine = ServeEngine(cfg, scfg, params, seed=seed)
    cache, st = engine.fresh_state()
    prompt = jnp.zeros((scfg.prompt_pad,), jnp.int32)
    for rid in range(scfg.max_slots):
        # half-budget requests: paged reads only the live pages while
        # the dense baseline always streams all max_len rows
        cache, st, out = engine._admit(
            params, cache, st, prompt, jnp.int32(scfg.prompt_pad),
            jnp.int32(scfg.max_len // 2), jnp.int32(rid))
    for _ in range(steps):
        cache, st, out = engine._decode(params, cache, st)
    return engine, cache, st, out


def _mixed_workload(n, max_slots, vocab, seed=3):
    """Mixed-length serving workload, generation budgets in [16, 256]:
    mostly short turns (log-uniform 16-48) with one long generation
    (log-uniform 192-256) per ``max_slots`` arrivals — the regime fixed
    batching handles worst, since every batch waits on its long
    member.  Deterministic by seed."""
    import math

    import numpy as np

    from repro.serve import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        lo, hi = ((192, 256) if i % max_slots == max_slots - 1
                  else (16, 48))
        gen = int(round(math.exp(rng.uniform(math.log(lo),
                                             math.log(hi)))))
        prompt = tuple(rng.randint(0, vocab, 8).tolist())
        reqs.append(Request(i, prompt, gen))
    return reqs


def bench_scheduler(cfg, params, *, requests, max_slots, budget):
    reqs = _mixed_workload(requests, max_slots, cfg.vocab_size, seed=3)
    max_len = 8 + 256
    scfg = ServeConfig(max_slots=max_slots, page_size=16, max_len=max_len,
                       prompt_pad=8, attn="ref")
    rows = []
    stats = {}
    for mode in ("continuous", "fixed"):
        engine = ServeEngine(cfg, scfg, params, seed=0)
        # untimed compile pass on a 2-request prefix
        engine.run(reqs[:2], continuous=mode == "continuous")
        _, s = engine.run(reqs, continuous=mode == "continuous")
        stats[mode] = s
    speed = (stats["continuous"]["tokens_per_s"]
             / max(stats["fixed"]["tokens_per_s"], 1e-9))
    trail = stats["continuous"]["occupancy_trail"]
    rows.append({
        "name": "serve/continuous_vs_fixed",
        "wall_s": stats["continuous"]["wall_s"],
        "wall_s_fixed": stats["fixed"]["wall_s"],
        "tokens_per_s": stats["continuous"]["tokens_per_s"],
        "tokens_per_s_fixed": stats["fixed"]["tokens_per_s"],
        "speedup_vs_fixed": speed,
        "steps": stats["continuous"]["steps"],
        "steps_fixed": stats["fixed"]["steps"],
        "tokens": stats["continuous"]["tokens"],
        "mean_occupancy": sum(trail) / max(len(trail), 1),
        "requests": requests, "max_slots": max_slots,
        "gen_min": 16, "gen_max": 256, "budget": budget,
    })
    return rows


def bench_decode_step(cfg, params, *, max_slots):
    scfg = dict(max_slots=max_slots, page_size=16, max_len=128,
                prompt_pad=8, attn="ref")
    rows = []
    kv_fp32 = kv_int8 = None
    for name, int8 in (("serve/decode_paged", False),
                       ("serve/decode_paged_int8", True)):
        sc = ServeConfig(kv_int8=int8, **scfg)
        engine, cache, st, out = _warm_engine(cfg, sc, params)
        pages = float(out["vals"]["serve/pages_in_use"])
        kv = kv_bytes_read(cfg, sc, pages)
        if int8:
            kv_int8 = kv
        else:
            kv_fp32 = kv
        wall, _ = _time_threaded(
            lambda s: engine._decode(params, s[0], s[1])[:2], (cache, st))
        rows.append({"name": name, "wall_s": wall,
                     "kv_bytes_per_step": kv, "pages_in_use": pages,
                     "max_slots": max_slots, "page_size": 16})
    rows[1]["kv_bytes_reduction"] = kv_fp32 / kv_int8

    # dense full-cache baseline at the same batch width: every slot
    # streams all max_len KV rows regardless of its actual length
    model = build(cfg)
    max_len = scfg["max_len"]
    cache = model.init_cache(max_slots, max_len, dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(0), (max_slots, 8),
                                 0, cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": prompts}, cache)
    step = jax.jit(make_decode_step(model, temperature=0.0))
    tok = jnp.zeros((max_slots, 1), jnp.int32)

    def dense_step(s):
        t, c, k = step(params, s[0], s[1], jnp.int32(32), s[2])
        return t, c, k

    wall, _ = _time_threaded(dense_step,
                             (tok, cache, jax.random.PRNGKey(1)))
    from repro.models import transformer
    cycle, n_units = transformer.layer_cycle(cfg)
    dense_kv = (2.0 * max_slots * max_len * cfg.n_kv_heads
                * cfg.resolved_head_dim * 4 * n_units * len(cycle))
    rows.append({"name": "serve/decode_dense", "wall_s": wall,
                 "kv_bytes_per_step": dense_kv,
                 "max_slots": max_slots, "max_len": max_len})
    return rows


def main(budget="small"):
    cfg = get_config("tiny-lm").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = 24 if budget == "small" else 48
    rows = bench_scheduler(cfg, params, requests=requests, max_slots=6,
                           budget=budget)
    rows += bench_decode_step(cfg, params, max_slots=6)
    for r in rows:
        if "speedup_vs_fixed" in r:
            extra = (f"speedup_vs_fixed={r['speedup_vs_fixed']:.2f}x "
                     f"steps={r['steps']}/{r['steps_fixed']} "
                     f"occ={r['mean_occupancy']:.2f}")
        elif "kv_bytes_reduction" in r:
            extra = (f"kv_bytes={r['kv_bytes_per_step']:.0f} "
                     f"reduction={r['kv_bytes_reduction']:.2f}x")
        else:
            extra = f"kv_bytes={r['kv_bytes_per_step']:.0f}"
        common.csv_row(r["name"], r["wall_s"], extra)
    merged = common.merge_rows(rows)
    print(f"# wrote {common.bench_json_path()} ({len(rows)} serve rows, "
          f"{len(merged)} total)", flush=True)


if __name__ == "__main__":
    main()
