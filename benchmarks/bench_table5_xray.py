"""Paper Table V: FedRand vs FedPow vs FedFiTS on X-ray-like imaging
(2-class pneumonia analogue), normal & attack modes."""
from __future__ import annotations

from benchmarks import common


def run(budget="small"):
    K = 10
    rounds = 15 if budget == "small" else 25
    model, fed, ev = common.make_setup("images", n_clients=K, n=2000,
                                       n_classes=2, sep=0.6)
    out = []
    for attack in [False, True]:
        for algo in ["fedrand", "fedpow", "fedfits"]:
            r = common.run_fl(model, fed, ev, algo=algo, rounds=rounds,
                              n_clients=K, attack=attack,
                              fedrand_c=0.7, fedpow_d=K, fedpow_m=6)
            r.pop("state")
            r.update({"K": K, "table": "V"})
            out.append(r)
    return out


def main():
    for r in run():
        name = f"table5/{r['algo']}/{'attack' if r['attack'] else 'normal'}"
        common.csv_row(name, r["wall_s"],
                       f"best_acc={r['best_acc']:.3f};cost={r['cost_client_rounds']:.0f}")


if __name__ == "__main__":
    main()
