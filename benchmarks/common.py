"""Shared harness for the paper-table benchmarks (SimEngine runs on
synthetic stand-ins for MNIST / X-ray / Crop — the container is offline)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import attacks, fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build


def bench_json_path() -> str:
    """The shared BENCH artifact path (env override at CALL time, so a
    test or CI job that sets BENCH_KERNELS_JSON after import still
    lands in the right file)."""
    return os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")


def merge_rows(rows, path=None):
    """Merge ``rows`` into the BENCH json NON-destructively: replace
    same-name rows, preserve every other row (kernel timings, driver
    rows, robustness cells from other benches).  EVERY bench writes
    through this, so registration order in benchmarks/run.py can never
    drop another bench's section."""
    path = path or bench_json_path()
    existing = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (json.JSONDecodeError, OSError):
            existing = []
    new_names = {r["name"] for r in rows}
    merged = [r for r in existing
              if r.get("name") not in new_names] + list(rows)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2)
    return merged


def make_setup(kind="images", n_clients=10, n=2000, seed=0, n_classes=10,
               arch=None, sep=None):
    arch = arch or ("paper-cnn" if kind == "images" else "paper-mlp")
    model = build(ARCHS[arch])
    fed, test = build_federation(
        seed, kind=kind, n=n, n_clients=n_clients, batch_size=32,
        n_classes=n_classes, sep=sep,
        n_features=22 if kind == "tabular" else 22)

    @jax.jit
    def eval_fn(params):
        l, m = model.loss(params, test)
        return {"test_loss": l, "test_acc": m["acc"]}

    return model, fed, eval_fn


def run_fl(model, fed, eval_fn, *, algo="fedfits", rounds=15, n_clients=10,
           attack=False, n_malicious=0, seed=1, **fed_kw):
    malicious = None
    data_attack = None
    if attack:
        n_mal = n_malicious or max(int(0.3 * n_clients), 1)  # paper-style 30%
        malicious = jnp.zeros((n_clients,)).at[jnp.arange(n_mal)].set(1.0)
        n_classes = model.cfg.vocab_size

        def data_attack(data, mal, rng):
            return {"y": attacks.label_flip(data["y"], n_classes, mal)}

    cfg = FedConfig(n_clients=n_clients, algorithm=algo, local_epochs=2,
                    local_lr=0.2, **fed_kw)
    t0 = time.time()
    state, hist = fedfits.run(model, cfg, fed.data_fn, rounds,
                              jax.random.PRNGKey(seed), eval_fn=eval_fn,
                              data_attack=data_attack, malicious=malicious)
    wall = time.time() - t0
    accs = [float(h["test_acc"]) for h in hist]
    return {
        "algo": algo, "attack": attack, "rounds": rounds,
        "final_acc": accs[-1], "best_acc": max(accs),
        "acc_curve": accs,
        "rounds_to_90pct_best": next(
            (i + 1 for i, a in enumerate(accs) if a >= 0.9 * max(accs)),
            rounds),
        "cost_client_rounds": float(state.cost_client_rounds),
        "cost_bytes_up": float(state.cost_bytes_up),
        "cost_bytes_down": float(state.cost_bytes_down),
        "participation_pct": 100.0 * float(
            (state.cum_selected > 0).mean()),
        "wall_s": round(wall, 2),
        "state": state,
    }


def csv_row(name, wall_s, derived):
    us = 1e6 * wall_s
    print(f"{name},{us:.0f},{derived}", flush=True)
