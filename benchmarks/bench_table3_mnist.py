"""Paper Table III: FedFiTS vs FedAvg on MNIST-like data, normal & attack
modes, varying client counts (scaled to the container budget)."""
from __future__ import annotations

from benchmarks import common


def run(budget="small"):
    ks = [10] if budget == "small" else [10, 20, 50]
    rounds = 15 if budget == "small" else 25
    out = []
    for K in ks:
        model, fed, ev = common.make_setup("images", n_clients=K,
                                           n=200 * K, sep=0.9)
        for attack in [False, True]:
            for algo in ["fedavg", "fedfits"]:
                r = common.run_fl(model, fed, ev, algo=algo, rounds=rounds,
                                  n_clients=K, attack=attack)
                r.pop("state")
                r.update({"K": K, "table": "III"})
                out.append(r)
    return out


def main():
    for r in run():
        name = f"table3/{r['algo']}/K{r['K']}/{'attack' if r['attack'] else 'normal'}"
        common.csv_row(name, r["wall_s"],
                       f"best_acc={r['best_acc']:.3f};cost={r['cost_client_rounds']:.0f}")


if __name__ == "__main__":
    main()
