"""Paper Fig. 7: tabular Crop-Recommendation cross-domain evaluation —
FedFiTS vs FedAvg/FedRand/FedPow, gap widening with client count."""
from __future__ import annotations

from benchmarks import common


def run(budget="small"):
    ks = [8, 16] if budget == "small" else [8, 16, 32]
    rounds = 12 if budget == "small" else 25
    out = []
    for K in ks:
        model, fed, ev = common.make_setup("tabular", n_clients=K,
                                           n=150 * K, n_classes=22, sep=1.2)
        for algo in ["fedavg", "fedrand", "fedpow", "fedfits"]:
            r = common.run_fl(model, fed, ev, algo=algo, rounds=rounds,
                              n_clients=K)
            r.pop("state")
            r.update({"K": K, "figure": "7"})
            out.append(r)
    return out


def main():
    for r in run():
        name = f"fig7/{r['algo']}/K{r['K']}"
        common.csv_row(name, r["wall_s"],
                       f"best_acc={r['best_acc']:.3f};tt90={r['rounds_to_90pct_best']}")


if __name__ == "__main__":
    main()
