"""Paper Figs. 10/11 + Table VI: fixed alpha=0.5 vs dynamic alpha, and the
client participation-ratio fairness proxy."""
from __future__ import annotations

from benchmarks import common


def run(budget="small"):
    K = 8
    rounds = 10 if budget == "small" else 25
    out = []
    for kind, tag in [("images", "mnist"), ("images", "mnist-m")]:
        # mnist-m analogue: same generator family, different seed/style
        model, fed, ev = common.make_setup(kind, n_clients=K, n=2400,
                                           seed=0 if tag == "mnist" else 42)
        for dyn in [False, True]:
            r = common.run_fl(model, fed, ev, algo="fedfits", rounds=rounds,
                              n_clients=K, alpha=0.5, dynamic_alpha=dyn)
            r.pop("state")
            r.update({"dataset": tag,
                      "alpha_mode": "dynamic" if dyn else "fixed0.5",
                      "figure": "10/11"})
            out.append(r)
    # Table VI participation ratios
    model, fed, ev = common.make_setup("images", n_clients=12, n=2400)
    for algo, kw in [("fedavg", {}), ("fedpow", {"fedpow_m": 6}),
                     ("fedfits", {"alpha": 0.5, "beta": 0.5,
                                  "dynamic_alpha": False}),
                     ("fedfits", {"alpha": 0.5, "beta": 0.1,
                                  "dynamic_alpha": False}),
                     ("fedfits", {"dynamic_alpha": True})]:
        r = common.run_fl(model, fed, ev, algo=algo, rounds=rounds,
                          n_clients=12, avail_prob=0.7, **kw)
        r.pop("state")
        r.update({"table": "VI", "config": f"{algo}/{kw}"})
        out.append(r)
    return out


def main():
    for r in run():
        if r.get("table") == "VI":
            common.csv_row(f"table6/{r['config']}", r["wall_s"],
                           f"participation={r['participation_pct']:.0f}%")
        else:
            common.csv_row(f"fig10/{r['dataset']}/{r['alpha_mode']}",
                           r["wall_s"], f"best_acc={r['best_acc']:.3f}")


if __name__ == "__main__":
    main()
