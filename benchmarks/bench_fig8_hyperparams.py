"""Paper Fig. 8/9: alpha-beta sensitivity under compromised clients.

Cases (paper SSVI-E): 1:(a=.5,b=.5)  2:(a=.5,b=.1)  3:(a=0,b=.01)
4:(a=1,b=.01); plus the beta-tuning sweep of Fig. 9."""
from __future__ import annotations

from benchmarks import common

CASES = [("case1", 0.5, 0.5), ("case2", 0.5, 0.1),
         ("case3", 0.0, 0.01), ("case4", 1.0, 0.01)]


def run(budget="small"):
    K = 10
    rounds = 12 if budget == "small" else 25
    model, fed, ev = common.make_setup("images", n_clients=K, n=2000)
    out = []
    for name, alpha, beta in CASES:
        r = common.run_fl(model, fed, ev, algo="fedfits", rounds=rounds,
                          n_clients=K, attack=True, n_malicious=3,
                          alpha=alpha, beta=beta, dynamic_alpha=False)
        mal_sel = float(r.pop("state").cum_selected[:3].sum())
        r.update({"case": name, "alpha": alpha, "beta": beta,
                  "malicious_selections": mal_sel, "figure": "8/9"})
        out.append(r)
    return out


def main():
    for r in run():
        common.csv_row(f"fig8/{r['case']}", r["wall_s"],
                       f"best_acc={r['best_acc']:.3f};mal_sel={r['malicious_selections']:.0f}")


if __name__ == "__main__":
    main()
