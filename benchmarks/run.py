"""Benchmark harness entry point — one bench per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; the kernels bench additionally
writes BENCH_kernels.json (the perf-trajectory artifact CI records).

  PYTHONPATH=src python -m benchmarks.run [--budget small|full] [--only X]
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback

BENCHES = [
    ("table3_mnist", "benchmarks.bench_table3_mnist"),
    ("table5_xray", "benchmarks.bench_table5_xray"),
    ("fig7_crop", "benchmarks.bench_fig7_crop"),
    ("fig8_hyperparams", "benchmarks.bench_fig8_hyperparams"),
    ("fig10_dynamic_alpha", "benchmarks.bench_fig10_dynamic_alpha"),
    ("communication", "benchmarks.bench_communication"),
    # order no longer matters for the JSON artifact: every bench merges
    # its rows by section key through common.merge_rows (replace
    # same-name rows, preserve the rest) instead of rewriting wholesale
    ("kernels", "benchmarks.bench_kernels"),
    ("scenarios", "benchmarks.bench_scenarios"),
    ("serve", "benchmarks.bench_serve"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small", choices=["small", "full"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failed = []
    for name, module in BENCHES:
        if args.only and args.only not in name:
            continue
        print(f"# ==== {name} ====", flush=True)
        try:
            mod = __import__(module, fromlist=["main"])
            if "budget" in inspect.signature(mod.main).parameters:
                mod.main(budget=args.budget)
            else:
                mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print("# FAILED:", ",".join(failed))
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
