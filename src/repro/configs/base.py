"""Model / federated / run configuration dataclasses and the arch registry.

Every assigned architecture is expressed as a ``ModelConfig`` whose
``block_pattern`` lists the per-layer block kind:

  attn    pre-norm self-attention + SwiGLU MLP           (dense archs)
  moe     pre-norm self-attention + top-k MoE FFN        (granite, dbrx)
  hybrid  pre-norm parallel attention ∥ mamba + MLP      (hymba)
  mlstm   matrix-memory xLSTM block (internal up/down)   (xlstm)
  slstm   scalar-memory xLSTM block with h-recurrence    (xlstm)
  xattn   pre-norm cross-attention (image) + MLP         (llama-3.2-vision)

The FULL configs below are exercised only via the dry-run
(ShapeDtypeStruct, no allocation); smoke tests instantiate
``reduced()`` variants (2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense|moe|ssm|hybrid|vlm|audio|cnn|mlp
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    norm_eps: float = 1.0e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 -> ceil(d_model/16)
    scan_chunk: int = 256             # chunked associative scan (memory cap)
    scan_unroll: bool = False         # unroll layer/chunk scans (dry-run cost
                                      # probes: HloCostAnalysis counts a while
                                      # body once, so probes must not loop)
    ssm_scan_dtype: str = "float32"   # mamba scan state/coeff dtype; bf16
                                      # halves the dominant HBM traffic of
                                      # the (B,chunk,d_inner,state) temporaries
    # --- block layout ---
    block_pattern: Tuple[str, ...] = ()   # empty -> derived from arch_type
    # --- VLM ---
    cross_attn_every: int = 0         # every Nth layer is 'xattn'
    n_image_tokens: int = 0           # frontend-stub token count
    # --- audio ---
    n_codebooks: int = 0              # frontend stub sums codebook embeddings
    embed_inputs: bool = True         # False: input_specs provides embeddings
    # --- attention ---
    sliding_window: int = 0           # 0 = full attention
    attn_impl: str = "xla"            # xla | pallas  (pallas = flash kernel)
    # --- numerics / memory ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    loss_chunk: int = 0               # chunk seq dim of the LM loss
    # --- provenance ---
    source: str = ""                  # citation of the public config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 128 so embeddings/head shard over a 16-way
        model axis (MaxText-style padding; padded logits masked to -inf)."""
        return -(-self.vocab_size // 128) * 128

    @property
    def resolved_dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def layers(self) -> Tuple[str, ...]:
        """Per-layer block kinds (derives the default pattern)."""
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        if self.arch_type in ("dense", "audio"):
            return ("attn",) * self.n_layers
        if self.arch_type == "moe":
            return ("moe",) * self.n_layers
        if self.arch_type == "hybrid":
            return ("hybrid",) * self.n_layers
        if self.arch_type == "ssm":
            # xLSTM[7:1]: every 8th block sLSTM, rest mLSTM (arXiv:2405.04517)
            return tuple(
                "slstm" if (i % 8) == 7 else "mlstm" for i in range(self.n_layers)
            )
        if self.arch_type == "vlm":
            every = self.cross_attn_every or 5
            return tuple(
                "xattn" if (i % every) == (every - 1) else "attn"
                for i in range(self.n_layers)
            )
        raise ValueError(f"unknown arch_type {self.arch_type}")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, 2))
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_image_tokens=min(self.n_image_tokens, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            block_pattern=(),
            remat=False,
            dtype="float32",
        )
        if self.n_experts:
            kw["n_experts"] = min(self.n_experts, 4)
            kw["top_k"] = min(self.top_k, 2)
            # no-drop capacity at smoke-test sizes: keeps decode-vs-full
            # comparisons exact (drops are a load-dependent approximation)
            kw["capacity_factor"] = float(kw["n_experts"])
        if self.arch_type == "ssm":
            # keep one of each xlstm kind
            kw["block_pattern"] = ("mlstm", "slstm")
        return self.replace(**kw)


# ----------------------------------------------------------------------
# Federated / FedFiTS configuration (paper §III)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class FedConfig:
    n_clients: int = 16               # C: client groups on the mesh / sim clients
    alpha: float = 0.5                # Eq.(2) data-quality vs performance
    dynamic_alpha: bool = True        # §V Eqs.(18-19)
    beta: float = 0.1                 # Eq.(3) threshold openness
    msl: int = 5                      # Maximum Slot Length
    pft: int = 2                      # Performance Fluctuation Threshold
    local_epochs: int = 1             # E
    local_lr: float = 0.1             # eta_l
    participation_floor: float = 0.0  # A4: Pr(i in S_t) >= p_min (quota)
    explore_eps: float = 0.0          # explore-exploit: eps-greedy inclusion
    # trust & robustness
    trust_decay: float = 0.9          # EWMA decay for BOTH trust tracks:
                                      # aggregation trust (score-driven) and
                                      # gate_trust (cosine-gate rejections)
    trust_in_fitness: bool = True     # fold the gate_trust EWMA into the
                                      # fitness scores (paper's "dynamic
                                      # client scoring"); behavior-preserving
                                      # while no client is ever gated
    cosine_outlier_thresh: float = -0.5   # gradient-cosine outlier gate
    aggregator: str = "fedavg"        # fedavg|median|trimmed_mean|krum
    trim_frac: float = 0.2            # trimmed-mean fraction per side
    krum_f: int = 1                   # assumed byzantine count for Krum
    fused_agg: bool = True            # route Eq.-11 through the fused
                                      # two-pass Pallas pipeline (False ->
                                      # multi-pass XLA reference)
    agg_blk: Optional[int] = None     # fused-pipeline streaming block size;
                                      # None -> autotuned from backend +
                                      # VMEM budget (robust_pipeline.auto_blk)
    paper_exact_agg: bool = False     # reproduce Algorithm 1's n_k/|S_t| literal
    # compressed client->server transport (repro/comm/)
    compress: str = "none"            # none|int8|int4|signsgd|topk|randk
    compress_qblk: int = 128          # quant-block width (per-block scales)
    compress_topk_frac: float = 0.05  # top-k kept fraction per leaf
    error_feedback: bool = True       # EF residual (carried in the scan
                                      # carry) re-injects compression error
    fused_dequant: bool = True        # int8: aggregate straight from the
                                      # wire codes (dequant in VMEM inside
                                      # the fused Eq.-11 kernels; False ->
                                      # decode-then-aggregate oracle)
    # aggregation-boundary guard: NaN/Inf or absurd-norm deliveries are
    # rejected (zeroed + masked out) with a gate-trust penalty instead of
    # entering the global model
    update_guard: bool = True
    guard_norm_mult: float = 1e4      # reject ||u|| > mult * median ||u||
    # population-scale / buffered-async round engine (core/async_engine)
    population: int = 0               # M registered clients (0 -> n_clients;
                                      # the cohort C = n_clients is SAMPLED
                                      # from the M-row ClientStore per round)
    async_deadline: float = 1.0       # per-round deadline the delivery races
    async_max_retries: int = 2        # late updates retry <= this many rounds
    async_backoff: float = 1.5        # retry window = deadline * backoff^age
    staleness_decay: float = 0.5      # buffered weight *= decay^age
    select_method: str = "segmented"  # population top-d engine:
                                      # argsort|segmented|pallas
    # selection algorithm: fedfits|fedavg|fedrand|fedpow
    algorithm: str = "fedfits"
    prox_mu: float = 0.0              # FedProx proximal term (baseline from
                                      # related work; also stabilises E>1)
    avail_prob: float = 1.0           # client availability (straggler sim)
    stale_weight: float = 0.0         # async catch-up: unavailable clients
                                      # submit stale updates at this weight
    fedrand_c: float = 0.5            # FedRand: m = cK
    fedpow_d: int = 0                 # FedPow candidate set size d (0 -> K)
    fedpow_m: int = 0                 # FedPow selected count m (0 -> K/2)
    fitness_every: int = 1            # rounds between fitness evaluations

    def __post_init__(self):
        # the buffered-async engine (population > 0) is dense-uplink
        # only: EF residual columns must live behind the ClientStore
        # boundary before a codec can ride the retry buffer. Catch the
        # combination at config build so launch flags fail fast instead
        # of deep inside make_async_round.
        if self.population > 0 and self.compress != "none":
            raise ValueError(
                f"compress={self.compress!r} is not supported by the "
                f"buffered-async engine (population={self.population}): "
                "the codec's EF residuals are per-cohort scan-carry "
                "columns, but async cohorts are resampled from the "
                "ClientStore every round. Drop --population/"
                "--async-deadline (sync engine supports every codec) or "
                "set compress='none' for async runs.")


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3.0e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"          # sgd|adam|adamw
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1.0e-8
    seed: int = 0
    microbatch: int = 0               # 0 = no accumulation
    eval_batch: int = 0               # per-client fitness-eval examples (0 -> gb//C)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1                     # >1 adds leading "pod" axis

    @property
    def axis_names(self):
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def shape(self):
        return (
            (self.pods, self.data, self.model)
            if self.pods > 1
            else (self.data, self.model)
        )


# ----------------------------------------------------------------------
# Input shapes assigned to this paper
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
