"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up/down projections.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_state=16,
    source="arXiv:2405.04517 (xLSTM), 350M config",
)
