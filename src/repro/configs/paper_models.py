"""The paper's own model scale: small CNN (X-ray/MNIST) and MLP (Crop tabular).

These drive the paper-faithful FedFiTS experiments (EXPERIMENTS.md
SSPaper-faithful). cnn/mlp arch_types are handled by models/small.py.
"""
from repro.configs.base import ModelConfig

# MNIST / X-ray style: 28x28 grayscale, 10 / 2 classes
CNN_CONFIG = ModelConfig(
    name="paper-cnn",
    arch_type="cnn",
    n_layers=2,               # conv blocks
    d_model=32,               # base channels
    n_heads=1,
    n_kv_heads=1,
    d_ff=128,                 # dense head width
    vocab_size=10,            # n_classes
    dtype="float32",
    remat=False,
    source="paper SSVI-A (Pneumonia X-ray / MNIST CNN)",
)

# Crop Recommendation: 22 features, 22 classes (paper SSVI-D)
MLP_CONFIG = ModelConfig(
    name="paper-mlp",
    arch_type="mlp",
    n_layers=3,
    d_model=22,               # n_features
    n_heads=1,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=22,            # n_classes
    dtype="float32",
    remat=False,
    source="paper SSVI-D (Crop Recommendation tabular)",
)

# ~100M decoder for the end-to-end FL-LM training example
TINY_LM = ModelConfig(
    name="tiny-lm",
    arch_type="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    dtype="float32",
    remat=False,
    source="in-repo ~100M example config",
)
