"""llama-3.2-vision-90b [vlm] — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

Vision encoder (ViT) + projector are a STUB: ``input_specs`` provides
precomputed, already-projected patch embeddings (B, n_image_tokens, d_model).
Every 5th layer is cross-attention (20 of 100 layers).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    n_image_tokens=1601,      # one 560x560 tile -> 1601 patch tokens
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B variant dims)",
)
