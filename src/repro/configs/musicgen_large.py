"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec frontend (mel-spectrogram + conv codec) is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S, d_model);
the decoder predicts codebook tokens over vocab=2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    embed_inputs=False,
    source="arXiv:2306.05284 (MusicGen)",
)
