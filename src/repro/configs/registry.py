"""Registry of the 10 assigned architectures + the paper's own models.

Each entry cites its public source config in ``source``.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig

# one module per arch for discoverability; configs defined there
from repro.configs.qwen25_14b import CONFIG as _qwen25_14b
from repro.configs.musicgen_large import CONFIG as _musicgen_large
from repro.configs.qwen2_72b import CONFIG as _qwen2_72b
from repro.configs.granite_moe_1b import CONFIG as _granite_moe_1b
from repro.configs.hymba_15b import CONFIG as _hymba_15b
from repro.configs.minitron_4b import CONFIG as _minitron_4b
from repro.configs.llama32_vision_90b import CONFIG as _llama32_vision_90b
from repro.configs.internlm2_20b import CONFIG as _internlm2_20b
from repro.configs.dbrx_132b import CONFIG as _dbrx_132b
from repro.configs.xlstm_350m import CONFIG as _xlstm_350m
from repro.configs.paper_models import CNN_CONFIG, MLP_CONFIG, TINY_LM

ARCHS = {
    "qwen2.5-14b": _qwen25_14b,
    "musicgen-large": _musicgen_large,
    "qwen2-72b": _qwen2_72b,
    "granite-moe-1b-a400m": _granite_moe_1b,
    "hymba-1.5b": _hymba_15b,
    "minitron-4b": _minitron_4b,
    "llama-3.2-vision-90b": _llama32_vision_90b,
    "internlm2-20b": _internlm2_20b,
    "dbrx-132b": _dbrx_132b,
    "xlstm-350m": _xlstm_350m,
    # the paper's own model scale (healthcare FL experiments)
    "paper-cnn": CNN_CONFIG,
    "paper-mlp": MLP_CONFIG,
    "tiny-lm": TINY_LM,
}

ASSIGNED = [k for k in ARCHS if not k.startswith(("paper-", "tiny-"))]


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
