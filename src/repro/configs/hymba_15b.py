"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    sliding_window=1024,      # hymba uses SWA on most layers
    source="arXiv:2411.13676 (Hymba)",
)
