"""Sharded checkpointing: pytree <-> directory of per-leaf .npy files with a
msgpack manifest. Works for any pytree (params, optimizer state, FedState);
on a real multi-host pod each host writes only the leaf shards it owns
(``process_index`` prefix), and restore re-shards via
``jax.device_put(..., sharding)``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out, treedef


def save(path: str, tree: Any, step: Optional[int] = None):
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(path, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Any, sharding_tree: Any = None):
    """Restore into the structure of ``like``; optionally device_put each
    leaf with the matching sharding from ``sharding_tree``."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(manifest["leaves"]) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"expected {len(flat_like)}")
    leaves = []
    for m in manifest["leaves"]:
        arr = np.load(os.path.join(path, m["file"]))
        if str(arr.dtype) != m["dtype"]:
            # ml_dtypes leaves (bfloat16, f8) load as void without the
            # dtype registration — reinterpret via the manifest dtype
            import ml_dtypes  # noqa: F401  (registers numpy dtypes)
            arr = arr.view(np.dtype(m["dtype"]))
        leaves.append(arr)
    if sharding_tree is not None:
        shards = jax.tree_util.tree_leaves(sharding_tree)
        leaves = [jax.device_put(l, s) for l, s in zip(leaves, shards)]
    else:
        leaves = [jnp.asarray(l) for l in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_")]
    return max(steps) if steps else None


def save_step(root: str, step: int, tree: Any):
    save(os.path.join(root, f"step_{step:08d}"), tree, step)


def restore_latest(root: str, like: Any, sharding_tree: Any = None):
    step = latest_step(root)
    if step is None:
        return None, None
    tree = restore(os.path.join(root, f"step_{step:08d}"), like, sharding_tree)
    return tree, step
