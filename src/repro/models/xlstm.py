"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory) [arXiv:2405.04517].

TPU adaptation:
  * mLSTM trains in *chunkwise-parallel* form — intra-chunk attention-like
    MXU matmuls + an inter-chunk recurrent carry (C_hat, n_hat, m) under a
    ``lax.scan`` — instead of a 1-step-per-token scan. Exponential gating is
    stabilised in log space (stabiliser m carried across chunks).
  * sLSTM keeps its inherently-sequential h-recurrence (per the paper it is
    not parallelisable) as a ``lax.scan`` over time, vectorised over
    batch/heads; the 350M config uses it only every 8th layer.
Decode for both is an O(1) recurrent step (long_500k friendly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (causal_depthwise_conv, dense_init,
                                 group_norm, init_rms_norm)

# ======================================================================
# mLSTM
# ======================================================================
def init_mlstm(key, cfg):
    d = cfg.d_model
    di = 2 * d                           # xLSTM pre-up-projection factor 2
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, di)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(ks[2], (di, di)),
        "wk": dense_init(ks[3], (di, di)),
        "wv": dense_init(ks[4], (di, di)),
        "wi": dense_init(ks[5], (di, H)),
        "bi": jnp.zeros((H,), jnp.float32),
        "wf": dense_init(ks[6], (di, H)),
        "bf": jnp.full((H,), 3.0, jnp.float32),   # forget-gate bias init high
        "gn": init_rms_norm(di)["scale"],
        "down": dense_init(ks[7], (di, d)),
    }


def _mlstm_inputs(params, xm, H, dtype):
    di = params["wq"].shape[0]
    dh = di // H
    q = (xm @ params["wq"].astype(dtype)).reshape(*xm.shape[:-1], H, dh)
    k = (xm @ params["wk"].astype(dtype)).reshape(*xm.shape[:-1], H, dh)
    v = (xm @ params["wv"].astype(dtype)).reshape(*xm.shape[:-1], H, dh)
    li = (xm @ params["wi"].astype(dtype)).astype(jnp.float32) + params["bi"]
    lf = jax.nn.log_sigmoid(
        (xm @ params["wf"].astype(dtype)).astype(jnp.float32) + params["bf"])
    return q, k / jnp.sqrt(dh).astype(dtype), v, li, lf


def mlstm_fwd(params, x, cfg, state=None):
    """x: (B, S, d). state {"C","n","m","conv"} for decode. -> (y, state)."""
    dtype = x.dtype
    H = cfg.n_heads
    uz = x @ params["up"].astype(dtype)
    xm, z = jnp.split(uz, 2, axis=-1)

    if state is not None and x.shape[1] == 1:   # ---- O(1) recurrent decode ----
        xc, conv_state = causal_depthwise_conv(
            xm, params["conv_w"], params["conv_b"], state["conv"])
        xc = jax.nn.silu(xc)
        q, k, v, li, lf = _mlstm_inputs(params, xc[:, 0], H, dtype)
        q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
        m_new = jnp.maximum(lf + state["m"], li)          # (B, H)
        fp = jnp.exp(lf + state["m"] - m_new)[..., None]
        ip = jnp.exp(li - m_new)[..., None]
        C = fp[..., None] * state["C"] + ip[..., None] * (k32[..., None] * v32[..., None, :])
        n = fp * state["n"] + ip * k32
        num = jnp.einsum("bhkv,bhk->bhv", C, q32)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q32)),
                          jnp.exp(-m_new))[..., None]
        h = (num / den).reshape(x.shape[0], 1, -1).astype(dtype)
        h = group_norm(h, params["gn"], H)
        out = (h * jax.nn.silu(z)) @ params["down"].astype(dtype)
        return out, {"C": C, "n": n, "m": m_new, "conv": conv_state}

    # ---- chunkwise-parallel form (train, or prefill when state given) ----
    B, S, d = x.shape
    if state is not None:
        K = params["conv_w"].shape[0]
        xm_ext = jnp.concatenate([state["conv"].astype(xm.dtype), xm], 1)
        xc_ext, _ = causal_depthwise_conv(
            xm_ext, params["conv_w"], params["conv_b"])
        xc = xc_ext[:, K - 1:]
        conv_tail = xm_ext[:, -(K - 1):]
    else:
        xc, _ = causal_depthwise_conv(xm, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    q, k, v, li, lf = _mlstm_inputs(params, xc, H, dtype)   # (B,S,H,dh), (B,S,H)
    di = q.shape[-1] * H
    dh = q.shape[-1]
    L = min(cfg.scan_chunk, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S

    # padded steps must not contribute: force their input gate to -inf
    if pad:
        li = jnp.concatenate(
            [li, jnp.full((B, pad, H), -1e30, li.dtype)], axis=1)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))

    def chunkify2(t):
        t = t.reshape(B, n_chunks, L, *t.shape[2:])
        return jnp.moveaxis(t, 1, 0)                        # (nc, B, L, ...)

    qc, kc, vc = chunkify2(q), chunkify2(k), chunkify2(v)
    lic, lfc = chunkify2(li), chunkify2(lf)

    if state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)

    tri = jnp.tril(jnp.ones((L, L), bool))

    def body(carry, inp):
        C_prev, n_prev, m_prev = carry
        q_, k_, v_, li_, lf_ = inp                          # (B,L,H,dh)/(B,L,H)
        q32, k32, v32 = (t.astype(jnp.float32) for t in (q_, k_, v_))
        b = jnp.cumsum(lf_, axis=1)                         # (B,L,H) log decay from chunk start
        g = jax.lax.cummax(li_ - b, axis=1)                 # (B,L,H)
        u = jnp.maximum(m_prev[:, None], g)                 # m_t = b_t + u_t
        # intra-chunk weights: w[t,s] = exp(li_s - b_s - u_t + b_t - b_t)... =
        #   exp((li_s - b_s) - u_t) for s <= t
        wlog = (li_ - b)[:, None, :, :] - u[:, :, None, :]  # (B,T,Sk,H)
        w = jnp.exp(jnp.where(tri[None, :, :, None], wlog, -jnp.inf))
        scores = jnp.einsum("bthd,bshd->btsh", q32, k32)
        h_intra = jnp.einsum("btsh,btsh,bshd->bthd", scores, w, v32)
        n_intra = jnp.einsum("btsh,bshd->bthd", w, k32)
        # inter-chunk: coeff exp(m_prev - u_t)
        c_int = jnp.exp(m_prev[:, None] - u)                # (B,L,H)
        h_inter = jnp.einsum("bthd,bhde->bthe", q32, C_prev) * c_int[..., None]
        n_inter = n_prev[:, None] * c_int[..., None]
        n_t = n_intra + n_inter
        m_t = b + u
        den = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, q32)),
                          jnp.exp(-m_t))[..., None]
        h_t = (h_intra + h_inter) / den                     # (B,L,H,dh)
        # carry update at chunk end: C_hat is the true C rescaled by e^{-m},
        # m_new = bL + uL, so each step-s term carries weight
        # exp(bL - b_s + li_s - m_new) = exp(li_s - b_s - uL)
        uL = u[:, -1]
        bL = b[:, -1]
        wC = jnp.exp((li_ - b) - uL[:, None])               # (B,L,H)
        C_new = jnp.exp(m_prev - uL)[..., None, None] * C_prev + \
            jnp.einsum("bsh,bshd,bshe->bhde", wC, k32, v32)
        n_new = jnp.exp(m_prev - uL)[..., None] * n_prev + \
            jnp.einsum("bsh,bshd->bhd", wC, k32)
        m_new = bL + uL
        return (C_new, n_new, m_new), h_t

    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc),
                                    unroll=n_chunks if cfg.scan_unroll else 1)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, n_chunks * L, di)[:, :S]
    h = group_norm(h.astype(dtype), params["gn"], H)
    out = (h * jax.nn.silu(z)) @ params["down"].astype(dtype)
    if state is not None:
        return out, {"C": Cf, "n": nf, "m": mf,
                     "conv": conv_tail.astype(state["conv"].dtype)}
    return out, None


def init_mlstm_state(params, batch, cfg, dtype=jnp.float32):
    H = cfg.n_heads
    di = params["wq"].shape[0]
    dh = di // H
    K = params["conv_w"].shape[0]
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di), dtype),
    }


# ======================================================================
# sLSTM
# ======================================================================
def init_slstm(key, cfg):
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 5)
    w = dense_init(ks[0], (d, 4 * d))               # gates i,f,z,o from x
    r = dense_init(ks[1], (H, dh, 4 * dh))          # block-diag recurrent
    dff = -(-int(d * 4 / 3) // 128) * 128   # 128-aligned for 16-way sharding
    return {
        "w": w,
        "r": r,
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "gn": init_rms_norm(d)["scale"],
        "up_g": dense_init(ks[2], (d, dff)),
        "up_u": dense_init(ks[4], (d, dff)),
        "down": dense_init(ks[3], (dff, d)),
    }


def _slstm_step(params, carry, gx, H):
    """gx: (B, 4d) pre-activations from x laid out as [i|f|z|o] blocks of d.

    carry: (c, n, m, h) each (B, H, dh).
    """
    c, n, m, h = carry
    B = gx.shape[0]
    d = h.shape[-1] * H
    dh = d // H
    rec = jnp.einsum("bhd,hde->bhe", h, params["r"])        # (B,H,4dh) [i|f|z|o]
    gx4 = gx.reshape(B, 4, H, dh)                           # gate-major blocks
    bias = params["b"].reshape(4, H, dh)
    g = gx4 + jnp.moveaxis(rec.reshape(B, H, 4, dh), 2, 1) + bias
    gi, gf, gz, go = g[:, 0], g[:, 1], g[:, 2], g[:, 3]     # (B,H,dh)
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    ip = jnp.exp(gi - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * jnp.tanh(gz)
    n_new = fp * n + ip
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_fwd(params, x, cfg, state=None):
    dtype = x.dtype
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    gx = (x @ params["w"].astype(dtype)).astype(jnp.float32)  # (B,S,4d)

    if state is not None and S == 1:
        carry = (state["c"], state["n"], state["m"], state["h"])
        carry, h = _slstm_step(params, carry, gx[:, 0], H)
        hseq = h[:, None].reshape(B, 1, d)
        new_state = dict(zip(("c", "n", "m", "h"), carry))
    else:
        if state is not None:
            init = (state["c"], state["n"], state["m"], state["h"])
        else:
            c0 = jnp.zeros((B, H, dh), jnp.float32)
            m0 = jnp.full((B, H, dh), -1e30, jnp.float32)
            init = (c0, c0, m0, c0)

        def body(carry, g):
            return _slstm_step(params, carry, g, H)

        final, hs = jax.lax.scan(body, init, jnp.moveaxis(gx, 1, 0))
        hseq = jnp.moveaxis(hs, 0, 1).reshape(B, S, d)
        new_state = (dict(zip(("c", "n", "m", "h"), final))
                     if state is not None else None)

    y = group_norm(hseq.astype(dtype), params["gn"], H)
    # post-up-projection (factor 4/3, GLU)
    u = jax.nn.gelu(y @ params["up_g"].astype(dtype)) * (y @ params["up_u"].astype(dtype))
    return u @ params["down"].astype(dtype), new_state


def init_slstm_state(params, batch, cfg, dtype=jnp.float32):
    d = params["gn"].shape[0]
    H = cfg.n_heads
    dh = d // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
            "h": z}
