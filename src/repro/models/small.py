"""The paper's own model scale: small CNN (MNIST / X-ray) and MLP (Crop tabular).

These are the models the FedFiTS experiments actually train (paper §VI);
they run per-client-replicated inside the SimEngine (core/fedfits.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_cnn(key, cfg, in_channels=1, image_size=28):
    """n_layers conv blocks (3x3, stride-2 pool) + dense head."""
    c = cfg.d_model
    ks = jax.random.split(key, cfg.n_layers + 2)
    params = {"convs": []}
    cin = in_channels
    size = image_size
    for i in range(cfg.n_layers):
        cout = c * (2 ** i)
        params["convs"].append({
            "w": dense_init(ks[i], (3, 3, cin, cout), in_axis=(0, 1, 2)),
            "b": jnp.zeros((cout,), jnp.float32),
        })
        cin = cout
        size = (size + 1) // 2
    feat = size * size * cin
    params["dense"] = {"w": dense_init(ks[-2], (feat, cfg.d_ff)),
                       "b": jnp.zeros((cfg.d_ff,), jnp.float32)}
    params["head"] = {"w": dense_init(ks[-1], (cfg.d_ff, cfg.vocab_size)),
                      "b": jnp.zeros((cfg.vocab_size,), jnp.float32)}
    return params


def cnn_fwd(params, x):
    """x: (B, H, W, C) -> logits (B, n_classes)."""
    for cp in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x, cp["w"], window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + cp["b"])
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["dense"]["w"] + params["dense"]["b"])
    return x @ params["head"]["w"] + params["head"]["b"]


def init_mlp_clf(key, cfg):
    ks = jax.random.split(key, cfg.n_layers + 1)
    dims = [cfg.d_model] + [cfg.d_ff] * (cfg.n_layers - 1) + [cfg.vocab_size]
    return {"layers": [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1])),
         "b": jnp.zeros((dims[i + 1],), jnp.float32)}
        for i in range(cfg.n_layers)
    ]}


def mlp_clf_fwd(params, x):
    """x: (B, F) -> logits (B, n_classes)."""
    for i, lp in enumerate(params["layers"]):
        x = x @ lp["w"] + lp["b"]
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


def classifier_loss(logits, labels):
    """(mean CE, accuracy) — fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
