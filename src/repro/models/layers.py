"""Shared parameter-init helpers and primitive layers (pure functional JAX).

Parameters are plain nested dicts of jnp arrays; every layer is an
``init_*(key, ...) -> params`` + ``*_fwd(params, x, ...) -> y`` pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = np.prod([shape[i] for i in np.atleast_1d(in_axis)])
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ----------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def group_norm(x, scale, n_groups, eps=1e-5):
    """Per-head group norm used by xLSTM cells. x: (..., d)."""
    *lead, d = x.shape
    x32 = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, d)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, dh); positions: (B, S) or (S,) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,dh/2)|(S,dh/2)
    if angles.ndim == 2:                                # (S, dh/2) -> (1,S,dh/2)
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------
def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    kg, ku, ko = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, (d_model, d_ff), dtype=dtype),
        "wu": dense_init(ku, (d_model, d_ff), dtype=dtype),
        "wo": dense_init(ko, (d_ff, d_model), dtype=dtype),
    }


def mlp_fwd(params, x, dtype):
    h = jax.nn.silu(x @ params["wg"].astype(dtype)) * (x @ params["wu"].astype(dtype))
    return h @ params["wo"].astype(dtype)


def causal_depthwise_conv(x, kernel, bias, state=None):
    """Causal depthwise 1D conv. x: (B, S, C); kernel: (K, C).

    If ``state`` (B, K-1, C) is given, runs a single-step decode update and
    returns (y, new_state) with S expected == 1.
    """
    K = kernel.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)      # (B, K, C)
        y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       kernel.astype(jnp.float32))[:, None]
        y = (y + bias.astype(jnp.float32)).astype(x.dtype)
        return y, window[:, 1:]
    pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                # (B, S+K-1, C)
    y = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        kernel[:, None, :].astype(jnp.float32),           # (K, 1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return (y + bias.astype(jnp.float32)).astype(x.dtype), None
