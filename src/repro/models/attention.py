"""GQA self-attention (full / sliding-window), cross-attention and KV caches.

Three execution modes per layer:
  * train/prefill: full-sequence attention, optional causal sliding window.
    ``attn_impl='pallas'`` routes the score/softmax/value contraction to the
    Pallas flash kernel (kernels/flash_attention.py).
  * decode (full cache): one query token against a (B, L, Hkv, dh) cache.
  * decode (ring cache, sliding window): (B, W, Hkv, dh) ring buffer —
    O(window) memory for the long_500k shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh)),
        "wk": dense_init(ks[1], (d, hkv * dh)),
        "wv": dense_init(ks[2], (d, hkv * dh)),
        "wo": dense_init(ks[3], (hq * dh, d)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    return p


def _proj(params, name, x, heads, dh, dtype):
    y = x @ params["w" + name].astype(dtype)
    if "b" + name in params:
        y = y + params["b" + name].astype(dtype)
    return y.reshape(*x.shape[:-1], heads, dh)


def _sdpa(q, k, v, mask):
    """q: (B,S,Hkv,G,dh); k/v: (B,T,Hkv,dh); mask: broadcastable (B,1,1,S,T)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bshgd,bthd->bhgst", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out


def causal_mask(s, t_offset=0, window=0):
    """(S, T) boolean mask; query i at absolute pos i+t_offset attends key j."""
    qpos = jnp.arange(s)[:, None] + t_offset
    kpos = jnp.arange(s + t_offset)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def attention_fwd(params, x, cfg, positions, *, window=0, cache=None,
                  kv_source=None, layer_idx=0):
    """Returns (out, new_cache).

    x: (B, S, d).  kv_source: (B, T, d) for cross-attention (no rope/causal).
    cache:
      None                     -> train/prefill, no cache returned
      {"k","v","length"}       -> full cache decode/prefill-fill
      {"k","v","pos"} (ring)   -> sliding-window ring cache decode
      {"ck","cv"}              -> frozen cross-attention KV
    """
    dtype = x.dtype
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    B, S, _ = x.shape

    q = _proj(params, "q", x, hq, dh, dtype)

    if kv_source is not None or (cache is not None and "ck" in cache):
        # ---- cross attention: prefill (kv_source given) computes + stores
        # the frozen KV; decode (S==1, no kv_source) reuses the cache ----
        if kv_source is None:
            k, v = cache["ck"], cache["cv"]
            new_cache = cache
        else:
            k = _proj(params, "k", kv_source, hkv, dh, dtype)
            v = _proj(params, "v", kv_source, hkv, dh, dtype)
            new_cache = {"ck": k.astype(cache["ck"].dtype),
                         "cv": v.astype(cache["cv"].dtype)} \
                if cache is not None else None
        qg = q.reshape(B, S, hkv, g, dh)
        mask = jnp.ones((1, 1, 1, S, k.shape[1]), bool)
        out = _sdpa(qg, k, v, mask)
        out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
        return out, new_cache

    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = _proj(params, "k", x, hkv, dh, dtype)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    v_new = _proj(params, "v", x, hkv, dh, dtype)

    if cache is None:                              # ---- train / prefill ----
        if cfg.attn_impl == "pallas" and S >= 128:
            from repro.kernels import flash_attention_ops
            out = flash_attention_ops.flash_attention(
                q, k_new, v_new, causal=True, window=window)
        else:
            qg = q.reshape(B, S, hkv, g, dh)
            mask = causal_mask(S, window=window)[None, None, None]
            out = _sdpa(qg, k_new, v_new, mask)
            out = out.reshape(B, S, hq * dh)
        out = out.astype(dtype).reshape(B, S, hq * dh) @ params["wo"].astype(dtype)
        return out, None

    if "pos" in cache and S > 1:                   # ---- ring-cache prefill ----
        W = cache["k"].shape[1]
        # full windowed attention for outputs, then fill the ring with the
        # last min(S, W) keys/values (assumes prefill starts at pos 0)
        qg = q.reshape(B, S, hkv, g, dh)
        mask = causal_mask(S, window=window)[None, None, None]
        out = _sdpa(qg, k_new, v_new, mask)
        out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
        take = min(S, W)
        slots = jnp.mod(jnp.arange(S - take, S), W)
        k = cache["k"].at[:, slots].set(k_new[:, -take:].astype(cache["k"].dtype))
        v = cache["v"].at[:, slots].set(v_new[:, -take:].astype(cache["v"].dtype))
        return out, {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}

    if "pos" in cache:                             # ---- ring-cache decode ----
        W = cache["k"].shape[1]
        pos = cache["pos"]                         # scalar absolute position
        slot = jnp.mod(pos, W)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        # slot j holds absolute position: the largest p <= pos with p % W == j
        j = jnp.arange(W)
        abs_pos = pos - jnp.mod(pos - j, W)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        if window:
            valid &= abs_pos > pos - window
        qg = q.reshape(B, S, hkv, g, dh)
        mask = valid[None, None, None, None, :]
        out = _sdpa(qg, k, v, mask)
        out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
        return out, {"k": k, "v": v, "pos": pos + 1}

    # ---- full-cache: prefill-fill or decode ----
    L = cache["k"].shape[1]
    length = cache["length"]                       # tokens already in cache
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, length, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, length, 0, 0))
    kpos = jnp.arange(L)
    qpos = length + jnp.arange(S)
    mask = kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    qg = q.reshape(B, S, hkv, g, dh)
    out = _sdpa(qg, k, v, mask[None, None, None])
    out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
    return out, {"k": k, "v": v, "length": length + S}


def init_kv_cache(cfg, batch, max_len, *, ring=False, dtype=jnp.bfloat16):
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, hkv, dh)
    z = jnp.zeros(shape, dtype)
    if ring:
        return {"k": z, "v": z, "pos": jnp.array(0, jnp.int32)}
    return {"k": z, "v": z, "length": jnp.array(0, jnp.int32)}
