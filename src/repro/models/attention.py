"""GQA self-attention (full / sliding-window), cross-attention and KV caches.

Three execution modes per layer:
  * train/prefill: full-sequence attention, optional causal sliding window.
    ``attn_impl='pallas'`` routes the score/softmax/value contraction to the
    Pallas flash kernel (kernels/flash_attention.py).
  * decode (full cache): one query token against a (B, L, Hkv, dh) cache.
  * decode (ring cache, sliding window): (B, W, Hkv, dh) ring buffer —
    O(window) memory for the long_500k shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh)),
        "wk": dense_init(ks[1], (d, hkv * dh)),
        "wv": dense_init(ks[2], (d, hkv * dh)),
        "wo": dense_init(ks[3], (hq * dh, d)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    return p


def _proj(params, name, x, heads, dh, dtype):
    y = x @ params["w" + name].astype(dtype)
    if "b" + name in params:
        y = y + params["b" + name].astype(dtype)
    return y.reshape(*x.shape[:-1], heads, dh)


def _sdpa(q, k, v, mask):
    """q: (B,S,Hkv,G,dh); k/v: (B,T,Hkv,dh); mask: broadcastable (B,1,1,S,T)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bshgd,bthd->bhgst", q.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(jnp.float32))
    return out


def causal_mask(s, t_offset=0, window=0):
    """(S, T) boolean mask; query i at absolute pos i+t_offset attends key j."""
    qpos = jnp.arange(s)[:, None] + t_offset
    kpos = jnp.arange(s + t_offset)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def attention_fwd(params, x, cfg, positions, *, window=0, cache=None,
                  kv_source=None, layer_idx=0):
    """Returns (out, new_cache).

    x: (B, S, d).  kv_source: (B, T, d) for cross-attention (no rope/causal).
    cache:
      None                     -> train/prefill, no cache returned
      {"k","v","length"}       -> full cache decode/prefill-fill
      {"k","v","pos"} (ring)   -> sliding-window ring cache decode
      {"kp","vp","table",...}  -> paged pool cache (serving; see
                                  init_paged_kv_cache)
      {"ck","cv"}              -> frozen cross-attention KV
    """
    dtype = x.dtype
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    B, S, _ = x.shape

    q = _proj(params, "q", x, hq, dh, dtype)

    if kv_source is not None or (cache is not None and "ck" in cache):
        # ---- cross attention: prefill (kv_source given) computes + stores
        # the frozen KV; decode (S==1, no kv_source) reuses the cache ----
        if kv_source is None:
            k, v = cache["ck"], cache["cv"]
            new_cache = cache
        else:
            k = _proj(params, "k", kv_source, hkv, dh, dtype)
            v = _proj(params, "v", kv_source, hkv, dh, dtype)
            new_cache = {"ck": k.astype(cache["ck"].dtype),
                         "cv": v.astype(cache["cv"].dtype)} \
                if cache is not None else None
        qg = q.reshape(B, S, hkv, g, dh)
        mask = jnp.ones((1, 1, 1, S, k.shape[1]), bool)
        out = _sdpa(qg, k, v, mask)
        out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
        return out, new_cache

    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = _proj(params, "k", x, hkv, dh, dtype)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    v_new = _proj(params, "v", x, hkv, dh, dtype)

    if cache is None:                              # ---- train / prefill ----
        if cfg.attn_impl == "pallas" and S >= 128:
            from repro.kernels import flash_attention_ops
            out = flash_attention_ops.flash_attention(
                q, k_new, v_new, causal=True, window=window)
        else:
            qg = q.reshape(B, S, hkv, g, dh)
            mask = causal_mask(S, window=window)[None, None, None]
            out = _sdpa(qg, k_new, v_new, mask)
            out = out.reshape(B, S, hq * dh)
        out = out.astype(dtype).reshape(B, S, hq * dh) @ params["wo"].astype(dtype)
        return out, None

    if "table" in cache:                           # ---- paged pool cache ----
        return _paged_fwd(params, cache, q, k_new, v_new, cfg, window)

    if "pos" in cache and S > 1:                   # ---- ring-cache prefill ----
        W = cache["k"].shape[1]
        # full windowed attention for outputs, then fill the ring with the
        # last min(S, W) keys/values (assumes prefill starts at pos 0)
        qg = q.reshape(B, S, hkv, g, dh)
        mask = causal_mask(S, window=window)[None, None, None]
        out = _sdpa(qg, k_new, v_new, mask)
        out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
        take = min(S, W)
        slots = jnp.mod(jnp.arange(S - take, S), W)
        k = cache["k"].at[:, slots].set(k_new[:, -take:].astype(cache["k"].dtype))
        v = cache["v"].at[:, slots].set(v_new[:, -take:].astype(cache["v"].dtype))
        return out, {"k": k, "v": v, "pos": jnp.asarray(S, jnp.int32)}

    if "pos" in cache:                             # ---- ring-cache decode ----
        W = cache["k"].shape[1]
        pos = cache["pos"]                         # scalar absolute position
        slot = jnp.mod(pos, W)
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
        # slot j holds absolute position: the largest p <= pos with p % W == j
        j = jnp.arange(W)
        abs_pos = pos - jnp.mod(pos - j, W)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        if window:
            valid &= abs_pos > pos - window
        qg = q.reshape(B, S, hkv, g, dh)
        mask = valid[None, None, None, None, :]
        out = _sdpa(qg, k, v, mask)
        out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
        return out, {"k": k, "v": v, "pos": pos + 1}

    # ---- full-cache: prefill-fill or decode ----
    L = cache["k"].shape[1]
    length = cache["length"]                       # tokens already in cache
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, length, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, length, 0, 0))
    kpos = jnp.arange(L)
    qpos = length + jnp.arange(S)
    mask = kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    qg = q.reshape(B, S, hkv, g, dh)
    out = _sdpa(qg, k, v, mask[None, None, None])
    out = out.reshape(B, S, hq * dh).astype(dtype) @ params["wo"].astype(dtype)
    return out, {"k": k, "v": v, "length": length + S}


def _paged_quant(x):
    """int8 KV append quantization: x (..., Hkv, dh) -> (codes int8 of
    x.shape, scales f32 of x.shape[:-1]).  One absmax scale per cache
    row per head (comm/codecs.py blockwise machinery with qblk = dh), so
    appends never touch other rows' scales and the fused kernel dequant
    is the exact quant_decode multiply."""
    from repro.comm import codecs
    flat = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    q, s = codecs.quant_encode(flat, x.shape[-1], 127.0)
    return q.reshape(x.shape), s.reshape(x.shape[:-1])


def _paged_fwd(params, cache, q, k_new, v_new, cfg, window):
    """Paged-pool branch of attention_fwd (serving; sliding windows are
    not supported here — the serving configs cap sequence length at the
    page budget instead).

    Cache contract (see init_paged_kv_cache):
      kp, vp    (N, page, Hkv, dh)  shared page pools (f32 or int8 codes)
      ks, vs    (N, page, Hkv) f32  per-(row, head) scales (int8 only)
      table     (A, maxp) int32     per-slot page table (unallocated = 0)
      length    (A,) int32          valid tokens already in the slot
      active    (A,) f32            1 = slot holds a live request
      new_valid (A,) int32          prefill only: valid rows of x to
                                    scatter (pad rows are dropped)

    Prefill (S > 1) scatters rows [0, new_valid) into the slot's pages;
    decode (S == 1) appends one row at position ``length`` per active
    slot and attends over the pages via the flash-decode kernel
    (cfg.attn_impl == 'pallas') or the dense gather reference.  The
    returned cache echoes the context leaves unchanged — the serving
    engine owns length/active advancement and eviction.
    """
    from repro.kernels.paged_decode import paged_flash_decode
    from repro.kernels.paged_decode_ref import paged_decode_ref

    dtype = k_new.dtype
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    B, S = q.shape[0], q.shape[1]
    kp, vp, table = cache["kp"], cache["vp"], cache["table"]
    n_pages, page = kp.shape[0], kp.shape[1]
    maxp = table.shape[1]
    int8 = "ks" in cache
    length, active = cache["length"], cache["active"]
    new_cache = dict(cache)

    if S > 1:
        # ---- prefill: causal attention over the (padded) prompt, then
        # scatter the valid rows into the slot's pages.  Pad rows are
        # dropped (dest = n_pages); rows beyond the prompt are garbage in
        # the output and the engine only reads position new_valid-1.
        qg = q.reshape(B, S, hkv, g, dh)
        mask = causal_mask(S, window=window)[None, None, None]
        out = _sdpa(qg, k_new, v_new, mask)
        out = out.reshape(B, S, hq * dh)
        pos = jnp.arange(S)
        valid = pos[None, :] < cache["new_valid"][:, None]       # (B, S)
        prow = jnp.clip(pos // page, 0, maxp - 1)
        pg = jnp.take_along_axis(table, jnp.broadcast_to(prow[None],
                                                         (B, S)), axis=1)
        dest = jnp.where(valid, pg, n_pages)       # n_pages = drop
        row = jnp.broadcast_to(pos % page, (B, S))
        if int8:
            kq, ks = _paged_quant(k_new)
            vq, vs = _paged_quant(v_new)
            new_cache["ks"] = cache["ks"].at[dest, row].set(ks, mode="drop")
            new_cache["vs"] = cache["vs"].at[dest, row].set(vs, mode="drop")
            k_cast, v_cast = kq, vq
        else:
            k_cast = k_new.astype(kp.dtype)
            v_cast = v_new.astype(vp.dtype)
        new_cache["kp"] = kp.at[dest, row].set(k_cast, mode="drop")
        new_cache["vp"] = vp.at[dest, row].set(v_cast, mode="drop")
        out = out.astype(dtype) @ params["wo"].astype(dtype)
        return out, new_cache

    # ---- decode: append one row at position ``length`` per active slot
    prow = jnp.clip(length // page, 0, maxp - 1)
    pg = jnp.take_along_axis(table, prow[:, None], axis=1)[:, 0]
    dest = jnp.where(active > 0, pg, n_pages)
    row = length % page
    if int8:
        kq, ks = _paged_quant(k_new[:, 0])
        vq, vs = _paged_quant(v_new[:, 0])
        new_cache["ks"] = cache["ks"].at[dest, row].set(ks, mode="drop")
        new_cache["vs"] = cache["vs"].at[dest, row].set(vs, mode="drop")
        k_cast, v_cast = kq, vq
        k_scale, v_scale = new_cache["ks"], new_cache["vs"]
    else:
        k_cast = k_new[:, 0].astype(kp.dtype)
        v_cast = v_new[:, 0].astype(vp.dtype)
        k_scale = v_scale = None
    kp = new_cache["kp"] = kp.at[dest, row].set(k_cast, mode="drop")
    vp = new_cache["vp"] = vp.at[dest, row].set(v_cast, mode="drop")
    n_keys = jnp.where(active > 0, length + 1, 0)
    attend = paged_flash_decode if cfg.attn_impl == "pallas" \
        else paged_decode_ref
    out3 = attend(q[:, 0], kp, vp, table, n_keys,
                  k_scale=k_scale, v_scale=v_scale)
    out = out3.reshape(B, 1, hq * dh)
    out = out.astype(dtype) @ params["wo"].astype(dtype)
    return out, new_cache


def init_kv_cache(cfg, batch, max_len, *, ring=False, dtype=jnp.bfloat16):
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, hkv, dh)
    z = jnp.zeros(shape, dtype)
    if ring:
        return {"k": z, "v": z, "pos": jnp.array(0, jnp.int32)}
    return {"k": z, "v": z, "length": jnp.array(0, jnp.int32)}


def init_paged_kv_cache(cfg, slots, num_pages, page_size, max_pages, *,
                        int8=False, dtype=jnp.float32):
    """One attention layer's paged pool cache (serving).  Pools are
    shared across slots; the per-slot page table indexes into them
    (unallocated entries stay 0 — always a valid pool index, masked out
    by length/active).  ``int8`` stores codes + per-(row, head) f32
    scales instead of raw K/V (see _paged_quant)."""
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    pool_dtype = jnp.int8 if int8 else dtype
    z = jnp.zeros((num_pages, page_size, hkv, dh), pool_dtype)
    c = {"kp": z, "vp": z,
         "table": jnp.zeros((slots, max_pages), jnp.int32),
         "length": jnp.zeros((slots,), jnp.int32),
         "active": jnp.zeros((slots,), jnp.float32),
         "new_valid": jnp.zeros((slots,), jnp.int32)}
    if int8:
        s = jnp.ones((num_pages, page_size, hkv), jnp.float32)
        c["ks"], c["vs"] = s, s
    return c
