"""Composable decoder-only transformer covering all assigned architectures.

Layer stacking: the per-layer block pattern (cfg.layers) is always a
repetition of a short *cycle* (length 1 for homogeneous stacks, 5 for the
VLM's every-5th cross-attn, 8 for xLSTM's 7:1 mix). Parameters for each
cycle *unit* are stacked along a leading axis and the stack is executed
with ``lax.scan`` — compile time scales with the cycle size, not with
n_layers (needed for the 80/100-layer dry-runs), and ``jax.checkpoint``
on the scan body gives per-unit activation rematerialisation.

Modes:
  train/prefill : full-sequence forward (cache=None -> no cache,
                  cache given -> prefill fills it)
  decode        : S=1 step against KV/SSM caches (decode_32k, long_500k)
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (dense_init, embed_init, init_mlp,
                                 init_rms_norm, mlp_fwd, rms_norm)


def layer_cycle(cfg):
    """The repeating unit of cfg.layers; (cycle, n_units)."""
    pattern = cfg.layers
    n = len(pattern)
    for c in range(1, n + 1):
        if n % c == 0 and pattern == pattern[:c] * (n // c):
            return pattern[:c], n // c
    return pattern, 1


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def _init_block(key, kind, cfg):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind == "attn":
        return {
            "ln1": init_rms_norm(d),
            "attn": attn_lib.init_attention(ks[0], cfg),
            "ln2": init_rms_norm(d),
            "mlp": init_mlp(ks[1], d, cfg.d_ff),
        }
    if kind == "moe":
        return {
            "ln1": init_rms_norm(d),
            "attn": attn_lib.init_attention(ks[0], cfg),
            "ln2": init_rms_norm(d),
            "moe": moe_lib.init_moe(ks[1], cfg),
        }
    if kind == "hybrid":
        return {
            "ln1": init_rms_norm(d),
            "attn": attn_lib.init_attention(ks[0], cfg),
            "mamba": ssm_lib.init_mamba(ks[1], cfg),
            "lna": init_rms_norm(d),
            "lnm": init_rms_norm(d),
            "ln2": init_rms_norm(d),
            "mlp": init_mlp(ks[2], d, cfg.d_ff),
        }
    if kind == "xattn":
        return {
            "ln1": init_rms_norm(d),
            "xattn": attn_lib.init_attention(ks[0], cfg, cross=True),
            "gate": jnp.zeros((), jnp.float32),   # zero-init cross-attn gate
            "ln2": init_rms_norm(d),
            "mlp": init_mlp(ks[1], d, cfg.d_ff),
        }
    if kind == "mlstm":
        return {"ln1": init_rms_norm(d), "mlstm": xlstm_lib.init_mlstm(ks[0], cfg)}
    if kind == "slstm":
        return {"ln1": init_rms_norm(d), "slstm": xlstm_lib.init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def init_transformer(key, cfg):
    cycle, n_units = layer_cycle(cfg)
    keys = jax.random.split(key, n_units + 3)
    units = []
    for u in range(n_units):
        uks = jax.random.split(keys[u], len(cycle))
        units.append({f"b{i}": _init_block(uks[i], kind, cfg)
                      for i, kind in enumerate(cycle)})
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *units) \
        if n_units > 1 else jax.tree_util.tree_map(lambda x: x[None], units[0])
    params = {"layers": stacked, "ln_f": init_rms_norm(cfg.d_model)}
    if cfg.embed_inputs:
        params["embed"] = embed_init(keys[-1], (cfg.padded_vocab, cfg.d_model))
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        params["lm_head"] = dense_init(keys[-2],
                                       (cfg.d_model, cfg.padded_vocab))
    return params


# ----------------------------------------------------------------------
# per-block forward
# ----------------------------------------------------------------------
def _block_fwd(bp, kind, x, cfg, positions, cache, image_embeds, window):
    dtype = x.dtype
    eps = cfg.norm_eps
    if kind in ("attn", "moe"):
        h, new_cache = attn_lib.attention_fwd(
            bp["attn"], rms_norm(x, bp["ln1"]["scale"], eps), cfg, positions,
            window=window, cache=cache)
        x = x + h
        y = rms_norm(x, bp["ln2"]["scale"], eps)
        if kind == "moe":
            m, aux = moe_lib.moe_fwd(bp["moe"], y, cfg)
        else:
            m, aux = mlp_fwd(bp["mlp"], y, dtype), 0.0
        return x + m, new_cache, aux
    if kind == "hybrid":
        y = rms_norm(x, bp["ln1"]["scale"], eps)
        a_cache = cache["attn"] if cache is not None else None
        m_cache = cache["mamba"] if cache is not None else None
        ha, na = attn_lib.attention_fwd(bp["attn"], y, cfg, positions,
                                        window=window, cache=a_cache)
        hm, nm = ssm_lib.mamba_fwd(bp["mamba"], y, cfg, state=m_cache)
        h = 0.5 * (rms_norm(ha, bp["lna"]["scale"], eps)
                   + rms_norm(hm, bp["lnm"]["scale"], eps))
        x = x + h
        y = rms_norm(x, bp["ln2"]["scale"], eps)
        new_cache = None if cache is None else {"attn": na, "mamba": nm}
        return x + mlp_fwd(bp["mlp"], y, dtype), new_cache, 0.0
    if kind == "xattn":
        h, new_cache = attn_lib.attention_fwd(
            bp["xattn"], rms_norm(x, bp["ln1"]["scale"], eps), cfg, positions,
            cache=cache, kv_source=image_embeds)
        x = x + jnp.tanh(bp["gate"]).astype(dtype) * h
        y = rms_norm(x, bp["ln2"]["scale"], eps)
        return x + mlp_fwd(bp["mlp"], y, dtype), new_cache, 0.0
    if kind == "mlstm":
        h, ns = xlstm_lib.mlstm_fwd(
            bp["mlstm"], rms_norm(x, bp["ln1"]["scale"], eps), cfg, state=cache)
        return x + h, ns, 0.0
    if kind == "slstm":
        h, ns = xlstm_lib.slstm_fwd(
            bp["slstm"], rms_norm(x, bp["ln1"]["scale"], eps), cfg, state=cache)
        return x + h, ns, 0.0
    raise ValueError(kind)


# ----------------------------------------------------------------------
# cache construction
# ----------------------------------------------------------------------
def init_cache(cfg, batch, max_len, *, ring=False, dtype=jnp.bfloat16):
    """Stacked (n_units-leading) cache pytree matching the layer scan."""
    cycle, n_units = layer_cycle(cfg)
    # ring caches bound memory at the sliding window size
    W = min(max_len, cfg.sliding_window) if (ring and cfg.sliding_window) else max_len

    def one(kind):
        if kind in ("attn", "moe"):
            return attn_lib.init_kv_cache(cfg, batch, W, ring=ring, dtype=dtype)
        if kind == "hybrid":
            mamba_p = {"A_log": jnp.zeros((cfg.d_inner, cfg.ssm_state)),
                       "conv_w": jnp.zeros((cfg.ssm_conv, cfg.d_inner))}
            return {"attn": attn_lib.init_kv_cache(cfg, batch, W, ring=ring,
                                                   dtype=dtype),
                    "mamba": ssm_lib.init_mamba_state(mamba_p, batch, cfg, dtype)}
        if kind == "xattn":
            hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
            z = jnp.zeros((batch, cfg.n_image_tokens, hkv, dh), dtype)
            return {"ck": z, "cv": z}
        if kind == "mlstm":
            di = 4 * cfg.d_model  # up-proj factor 2 -> d_inner = 2*d ; wq in di
            H = cfg.n_heads
            dh = (2 * cfg.d_model) // H
            return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
                    "n": jnp.zeros((batch, H, dh), jnp.float32),
                    "m": jnp.full((batch, H), -1e30, jnp.float32),
                    "conv": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * cfg.d_model),
                                      dtype)}
        if kind == "slstm":
            H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
            z = jnp.zeros((batch, H, dh), jnp.float32)
            return {"c": z, "n": z,
                    "m": jnp.full((batch, H, dh), -1e30, jnp.float32), "h": z}
        raise ValueError(kind)

    unit = {f"b{i}": one(kind) for i, kind in enumerate(cycle)}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape), unit)


# ----------------------------------------------------------------------
# full forward
# ----------------------------------------------------------------------
def forward(params, cfg, *, tokens=None, embeds=None, image_embeds=None,
            positions=None, cache=None, collect_logits=True):
    """Returns (logits or hidden, new_cache, aux_loss).

    tokens: (B, S) int32 or embeds: (B, S, d) when cfg.embed_inputs=False.
    """
    dtype = jnp.dtype(cfg.dtype)
    if embeds is None:
        x = params["embed"].astype(dtype)[tokens]
    else:
        x = embeds.astype(dtype)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    cycle, n_units = layer_cycle(cfg)
    window = cfg.sliding_window

    def unit_fwd(x, unit_params, unit_cache):
        new_cache = {} if unit_cache is not None else None
        aux = 0.0
        for i, kind in enumerate(cycle):
            c_in = None if unit_cache is None else unit_cache[f"b{i}"]
            x, c_out, a = _block_fwd(unit_params[f"b{i}"], kind, x, cfg,
                                     positions, c_in, image_embeds, window)
            if new_cache is not None:
                new_cache[f"b{i}"] = c_out
            aux = aux + a
        return x, new_cache, aux

    if cfg.remat:
        unit_fwd = jax.checkpoint(unit_fwd)

    def scan_body(x, xs):
        unit_params, unit_cache = xs
        x, new_cache, aux = unit_fwd(x, unit_params, unit_cache)
        return x, (new_cache, aux)

    if cfg.scan_unroll:
        # python loop over units (dry-run cost probes / tiny models):
        # avoids while-loops so HloCostAnalysis sees every layer
        aux = 0.0
        caches = []
        for u in range(n_units):
            up = jax.tree_util.tree_map(lambda l: l[u], params["layers"])
            uc = (None if cache is None else
                  jax.tree_util.tree_map(lambda l: l[u], cache))
            x, nc, a = unit_fwd(x, up, uc)
            aux = aux + a
            caches.append(nc)
        new_cache = (None if cache is None else
                     jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                            *caches))
        aux = jnp.asarray(aux)
    elif cache is None:
        # scan over units with cache=None: ys carries only aux
        def body_nc(x, up):
            x, _, aux = unit_fwd(x, up, None)
            return x, aux
        x, auxs = jax.lax.scan(body_nc, x, params["layers"])
        new_cache = None
        aux = jnp.sum(jnp.asarray(auxs))
    else:
        x, (new_cache, auxs) = jax.lax.scan(scan_body, x,
                                            (params["layers"], cache))
        aux = jnp.sum(jnp.asarray(auxs))

    x = rms_norm(x, params["ln_f"]["scale"], cfg.norm_eps)
    if not collect_logits:
        return x, new_cache, aux
    logits = lm_head(params, cfg, x)
    return logits, new_cache, aux


def lm_head(params, cfg, x):
    dtype = x.dtype
    if "lm_head" in params:
        logits = x @ params["lm_head"].astype(dtype)
    else:
        logits = x @ params["embed"].astype(dtype).T
    if cfg.padded_vocab != cfg.vocab_size:
        # mask padded vocab entries out of softmax/argmax
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


def cross_entropy(logits, targets, mask=None):
    """Mean CE over valid tokens; also returns accuracy. fp32 numerics."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ll = logz - gold
    correct = (jnp.argmax(logits, -1) == targets).astype(jnp.float32)
    if mask is None:
        mask = jnp.ones_like(ll)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (ll * mask).sum() / denom, (correct * mask).sum() / denom


def loss_fn(params, cfg, batch):
    """batch: {tokens|embeds, targets, [image_embeds], [mask]} -> (loss, metrics).

    cfg.loss_chunk > 0 chunks the LM head + CE over the sequence dim to
    avoid materialising (B, S, vocab) logits.
    """
    hidden, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        image_embeds=batch.get("image_embeds"), collect_logits=False)
    targets = batch["targets"]
    mask = batch.get("mask")
    S = hidden.shape[1]
    chunk = cfg.loss_chunk
    if chunk and S > chunk and S % chunk == 0:
        n = S // chunk
        h = hidden.reshape(hidden.shape[0], n, chunk, -1).transpose(1, 0, 2, 3)
        t = targets.reshape(targets.shape[0], n, chunk).transpose(1, 0, 2)
        m = (mask.reshape(mask.shape[0], n, chunk).transpose(1, 0, 2)
             if mask is not None else jnp.ones_like(t, jnp.float32))

        def body(carry, xs):
            hc, tc, mc = xs
            logits = lm_head(params, cfg, hc).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, tc[..., None], -1)[..., 0]
            correct = (jnp.argmax(logits, -1) == tc).astype(jnp.float32)
            loss_sum, acc_sum, msum = carry
            return (loss_sum + ((logz - gold) * mc).sum(),
                    acc_sum + (correct * mc).sum(), msum + mc.sum()), None

        (ls, accs, ms), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (h, t, m),
            unroll=n if cfg.scan_unroll else 1)
        loss = ls / jnp.maximum(ms, 1.0)
        acc = accs / jnp.maximum(ms, 1.0)
    else:
        logits = lm_head(params, cfg, hidden)
        loss, acc = cross_entropy(logits, targets, mask)
    total = loss + aux
    return total, {"loss": loss, "acc": acc, "aux": aux}
