"""Top-k MoE FFN with sort-based capacity dispatch (GShard-style dropping).

TPU-native design notes:
  * dispatch = argsort by expert id + rank-in-expert scatter into a dense
    (E, C, d) buffer -> the expert matmuls are plain MXU einsums and the
    scatter/gather lower to all-to-all when experts are sharded over the
    "model" mesh axis.
  * capacity C = tokens * top_k * capacity_factor / E  (rounded up to 8).
  * Switch-style load-balance auxiliary loss is returned for the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, cfg):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, ko = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, (d, e)),
        "wg": dense_init(kg, (e, d, ff), in_axis=1),
        "wu": dense_init(ku, (e, d, ff), in_axis=1),
        "wo": dense_init(ko, (e, ff, d), in_axis=1),
    }


def _capacity(n_tokens, cfg):
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_fwd(params, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    dtype = x.dtype
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    xf = x.reshape(N, d)

    logits = (xf @ params["router"].astype(dtype)).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                              # (N, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e fraction_routed_e * mean_prob_e
    frac = jnp.mean(
        (jax.nn.one_hot(top_e, E, dtype=jnp.float32)).sum(1), axis=0)   # (E,)
    aux = E * jnp.sum(frac * probs.mean(0)) * cfg.router_aux_weight

    # ---- sort-based dispatch ------------------------------------------
    C = _capacity(N, cfg)
    flat_e = top_e.reshape(-1)                                          # (N*K,)
    flat_tok = jnp.repeat(jnp.arange(N), K)
    flat_w = top_p.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank of each routed pair within its expert
    same = jnp.cumsum(jnp.ones_like(sorted_e))
    start = jnp.searchsorted(sorted_e, jnp.arange(E))                   # (E,)
    rank = (same - 1) - start[sorted_e]
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)                  # drop slot

    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[dest].set(xf[flat_tok[order]])
    eb = buf[: E * C].reshape(E, C, d)

    # ---- expert compute (MXU einsums; E shards over "model") ----------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, params["wg"].astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", eb, params["wu"].astype(dtype))
    eo = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dtype))
    eo = eo.reshape(E * C, d)
    eo = jnp.concatenate([eo, jnp.zeros((1, d), dtype)], axis=0)

    # ---- combine -------------------------------------------------------
    gathered = eo[dest] * (flat_w[order] * keep).astype(dtype)[:, None]
    out = jnp.zeros((N, d), dtype).at[flat_tok[order]].add(gathered)
    return out.reshape(B, S, d), aux
