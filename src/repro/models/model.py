"""Model facade: a uniform init/loss/forward API over every arch family.

``build(cfg)`` returns a ``Model`` with:
  init(key)                      -> params
  loss(params, batch)            -> (loss, metrics)   # train step objective
  forward(params, batch)         -> logits            # full-sequence
  init_cache(batch, max_len)     -> cache pytree      # decode shapes
  prefill(params, batch, cache)  -> (logits, cache)
  decode(params, token_batch, cache, pos) -> (logits, cache)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import small, transformer


@dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    loss: Callable
    forward: Callable
    init_cache: Callable = None
    prefill: Callable = None
    decode: Callable = None


def build(cfg) -> Model:
    if cfg.arch_type == "cnn":
        def init(key):
            return small.init_cnn(key, cfg)

        def loss(params, batch):
            logits = small.cnn_fwd(params, batch["x"])
            l, a = small.classifier_loss(logits, batch["y"])
            return l, {"loss": l, "acc": a}

        return Model(cfg, init, loss,
                     forward=lambda p, b: small.cnn_fwd(p, b["x"]))

    if cfg.arch_type == "mlp":
        def init(key):
            return small.init_mlp_clf(key, cfg)

        def loss(params, batch):
            logits = small.mlp_clf_fwd(params, batch["x"])
            l, a = small.classifier_loss(logits, batch["y"])
            return l, {"loss": l, "acc": a}

        return Model(cfg, init, loss,
                     forward=lambda p, b: small.mlp_clf_fwd(p, b["x"]))

    # ---- decoder transformers (all assigned archs) ----
    def init(key):
        return transformer.init_transformer(key, cfg)

    def loss(params, batch):
        return transformer.loss_fn(params, cfg, batch)

    def forward(params, batch):
        logits, _, _ = transformer.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            image_embeds=batch.get("image_embeds"))
        return logits

    def init_cache(batch_size, max_len, ring=False, dtype=jnp.bfloat16):
        return transformer.init_cache(cfg, batch_size, max_len, ring=ring,
                                      dtype=dtype)

    def prefill(params, batch, cache):
        # last-position logits only: full (B, S, vocab) logits at 32k x 152k
        # would dominate memory and nothing downstream needs them
        hidden, cache, _ = transformer.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            image_embeds=batch.get("image_embeds"), cache=cache,
            collect_logits=False)
        logits = transformer.lm_head(params, cfg, hidden[:, -1:])
        return logits, cache

    def decode(params, batch, cache, pos):
        """batch: {tokens: (B,1)} or {embeds: (B,1,d)}; pos: scalar int32."""
        B = (batch.get("tokens") if batch.get("tokens") is not None
             else batch.get("embeds")).shape[0]
        positions = jnp.broadcast_to(pos, (B, 1))
        logits, cache, _ = transformer.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), positions=positions, cache=cache)
        return logits, cache

    return Model(cfg, init, loss, forward, init_cache, prefill, decode)
