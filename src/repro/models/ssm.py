"""Mamba-style selective SSM block (used standalone and inside hybrid layers).

TPU adaptation: the selective scan runs as a *chunked associative scan* —
``lax.scan`` over chunks of ``cfg.scan_chunk`` steps carrying the hidden
state, with a log-depth ``lax.associative_scan`` inside each chunk. This
bounds the (B, chunk, d_inner, state) temporaries (VMEM/HBM friendly)
instead of materialising the full (B, S, d_inner, state) tensor, and keeps
the inner dimension shardable over the "model" mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_depthwise_conv, dense_init


def init_mamba(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    di = cfg.ssm_expand * d
    st, dtr, K = cfg.ssm_state, cfg.resolved_dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    # S4D-real A initialisation: A = -(1..state)
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di)),
        "conv_w": dense_init(ks[1], (K, di)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * st)),
        "dt_proj": dense_init(ks[3], (dtr, di)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d)),
    }


def _ssm_coeffs(params, xc, cfg, dtype, step_mask=None):
    """xc: (..., di) conv output -> decay a and input b, plus C for readout.

    step_mask zeroes dt on padded steps so they are identity transitions
    (a=1, b=0) and do not perturb the carried state.
    """
    st, dtr = cfg.ssm_state, cfg.resolved_dt_rank
    dbc = (xc @ params["x_proj"].astype(dtype)).astype(jnp.float32)
    dt_r, Bm, Cm = jnp.split(dbc, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])                  # (..., di)
    if step_mask is not None:
        dt = dt * step_mask
    A = -jnp.exp(params["A_log"])                              # (di, st)
    a = jnp.exp(dt[..., None] * A)                             # (..., di, st)
    b = (dt * xc.astype(jnp.float32))[..., None] * Bm[..., None, :]
    sd = jnp.dtype(cfg.ssm_scan_dtype)
    return a.astype(sd), b.astype(sd), Cm


def mamba_fwd(params, x, cfg, state=None):
    """x: (B, S, d). state: {"h": (B,di,st), "conv": (B,K-1,di)} for decode.

    Returns (y, new_state or None).
    """
    dtype = x.dtype
    di = params["A_log"].shape[0]
    xz = x @ params["in_proj"].astype(dtype)
    xin, z = jnp.split(xz, 2, axis=-1)

    if state is not None and x.shape[1] == 1:      # ---- single-step decode ----
        xc, conv_state = causal_depthwise_conv(
            xin, params["conv_w"], params["conv_b"], state["conv"])
        xc = jax.nn.silu(xc)[:, 0]                 # (B, di)
        a, b, Cm = _ssm_coeffs(params, xc, cfg, dtype)
        h = a * state["h"] + b                     # (B, di, st)
        y = jnp.einsum("bds,bs->bd", h, Cm) + params["D"] * xc.astype(jnp.float32)
        y = (y.astype(dtype) * jax.nn.silu(z[:, 0]))[:, None]
        out = y @ params["out_proj"].astype(dtype)
        return out, {"h": h, "conv": conv_state}

    # ---- full sequence (train, or prefill when state is given) ----
    B, S, _ = x.shape
    if state is not None:
        # prefill: seed conv left-context and h from the carried state
        K = params["conv_w"].shape[0]
        xin_ext = jnp.concatenate([state["conv"].astype(xin.dtype), xin], 1)
        xc_ext, _ = causal_depthwise_conv(
            xin_ext, params["conv_w"], params["conv_b"])
        xc = xc_ext[:, K - 1:]
        conv_tail = xin_ext[:, -(K - 1):]
    else:
        xc, _ = causal_depthwise_conv(xin, params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    chunk = min(cfg.scan_chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    xc_p = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    xc_c = xc_p.reshape(B, n_chunks, chunk, di).transpose(1, 0, 2, 3)
    smask = (jnp.arange(n_chunks * chunk) < S).astype(jnp.float32)
    smask_c = smask.reshape(n_chunks, 1, chunk, 1)

    st = cfg.ssm_state
    sd = jnp.dtype(cfg.ssm_scan_dtype)
    h0 = (state["h"].astype(sd) if state is not None
          else jnp.zeros((B, di, st), sd))

    def body(h_prev, xs):                           # xck: (B, chunk, di)
        xck, mk = xs                                # mk: (1, chunk, 1)
        a, b, Cm = _ssm_coeffs(params, xck, cfg, dtype, step_mask=mk)
        # prepend carried state as step 0 contribution: h_t = a_t h_{t-1} + b_t
        b = b.at[:, 0].add(a[:, 0] * h_prev)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
        y = jnp.einsum("bcds,bcs->bcd", hh.astype(jnp.float32), Cm)
        return hh[:, -1], y

    h_last, ys = jax.lax.scan(body, h0, (xc_c, smask_c),
                              unroll=n_chunks if cfg.scan_unroll else 1)
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, di)[:, :S]
    y = y + params["D"] * xc.astype(jnp.float32)
    y = y.astype(dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dtype)
    if state is not None:
        return out, {"h": h_last.astype(state["h"].dtype),
                     "conv": conv_tail.astype(state["conv"].dtype)}
    return out, None


def init_mamba_state(params, batch, cfg, dtype=jnp.float32):
    di = params["A_log"].shape[0]
    K = params["conv_w"].shape[0]
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di), dtype),
    }
