"""Minimal optimizer substrate (no optax in this environment).

API mirrors optax: ``init(params) -> state``, ``update(grads, state, params)
-> (updates, state)``; apply with ``apply_updates``. All states are pytrees
so they shard like the params they track.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: any
    count: jnp.ndarray


class AdamState(NamedTuple):
    mu: any
    nu: any
    count: jnp.ndarray


def _zeros_like(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def sgd(lr_fn, momentum=0.9):
    def init(params):
        return SGDState(_zeros_like(params), jnp.int32(0))

    def update(grads, state, params=None):
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state.momentum, grads)
        lr = lr_fn(state.count)
        upd = jax.tree_util.tree_map(lambda m: -lr * m, mu)
        return upd, SGDState(mu, state.count + 1)

    return init, update


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0):
    def init(params):
        return AdamState(_zeros_like(params), _zeros_like(params),
                         jnp.int32(0))

    def update(grads, state, params):
        c = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)
        lr = lr_fn(state.count)

        def upd(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step

        return (jax.tree_util.tree_map(upd, mu, nu, params),
                AdamState(mu, nu, c))

    return init, update


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def make_optimizer(train_cfg):
    lr_fn = warmup_cosine(train_cfg.lr, train_cfg.warmup_steps,
                          train_cfg.total_steps)
    if train_cfg.optimizer == "sgd":
        return sgd(lr_fn)
    if train_cfg.optimizer in ("adam", "adamw"):
        wd = train_cfg.weight_decay if train_cfg.optimizer == "adamw" else 0.0
        return adamw(lr_fn, train_cfg.b1, train_cfg.b2, train_cfg.eps, wd)
    raise ValueError(train_cfg.optimizer)


def warmup_cosine(peak, warmup, total):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return lr
