"""Pallas kernels of the compressed-transport subsystem."""
