"""Fused dequant-into-aggregation Pallas kernels for the int8 uplink.

The server never materialises dense per-client updates: both passes of
the Eq.-11 robust pipeline (``kernels/robust_pipeline.py``) get variants
here whose per-leaf inputs are the **encoded** int8 code matrices plus
their per-(client, quant-block) f32 scales, dequantized in VMEM right
after the block DMA:

  pass 1   streams int8 (C, blk) code blocks + (C, blk/qblk) scale
           blocks; dequantizes in VMEM (one multiply against the
           broadcast scales) and feeds the SAME median-reference /
           cosine-partial accumulation as the dense engine.
  pass 2   same dequant load, same gated combine; per-leaf outputs in
           the caller's dtypes.
  krum     same dequant load into the blocked Gram accumulation.

HBM traffic: the dense engine reads ``C*N*4`` bytes per pass; this one
reads ``C*N*1`` code bytes + ``C*N*4/qblk`` scale bytes — a ~4x cut per
pass at qblk=128, ON TOP of the 2-pass (3 for Krum) streaming roofline.
The decode-then-aggregate path (``codecs.quant_decode`` into the dense
engine) is retained as the parity oracle: the kernel's in-VMEM dequant
replays the exact ``q_f32 * scale_f32`` multiply of ``quant_decode``, so
the two are **bit-identical** (tested), and both sit within quantization
error of the dense fp32 oracle.

Layout contract: every per-leaf streaming block ``seg.blk`` is a
multiple of 128 (``make_segments``), so any ``qblk`` dividing 128 (or
equal to it) tiles the block exactly; ``fusable`` checks the general
condition and callers fall back to decode-then-aggregate when it fails.
Under ``shard_map`` (``fused_dequant_aggregate_sharded``) the flattened
code axis shards over the mesh with its scale columns riding along
(alignment guaranteed by the ``align=qblk`` leg of
``sharding.specs.client_flat_specs``); only the (C,) cosine partials and
Krum's Gram matrix cross devices, exactly like the dense sharded path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.comm import codecs
from repro.kernels import robust_pipeline as rp


def _dq_block(q_refs, s_refs, l, seg, i, qblk):
    """Load leaf ``l``'s current int8 (C, blk) code block and its
    (C, blk/qblk) scale block, dequantize in VMEM, and mask the ragged
    tail (same contract as ``robust_pipeline._leaf_block``).  The
    multiply is the exact op ``codecs.quant_decode`` performs, so the
    fused path is bit-identical to decode-then-aggregate."""
    q = q_refs[l][0].astype(jnp.float32)                 # (C, blk)
    s = s_refs[l][0].astype(jnp.float32)                 # (C, blk/qblk)
    c = q.shape[0]
    sb = seg.blk // qblk
    x = (q.reshape(c, sb, qblk) * s[:, :, None]).reshape(c, seg.blk)
    if seg.n % seg.blk:
        valid = seg.n - (i - seg.start) * seg.blk
        col = jax.lax.broadcasted_iota(jnp.int32, (1, seg.blk), 1)
        x = jnp.where(col < valid, x, 0.0)
    return x


def fusable(sizes, c, blk, qblk):
    """True when every per-leaf streaming block (at the blk the pipeline
    would actually run — ``auto_blk`` when unpinned) is tiled exactly by
    the quant block."""
    if blk is None:
        blk = rp.auto_blk(c, sizes)
    segs, _ = rp.make_segments(sizes, blk)
    return all(seg.blk % qblk == 0 for seg in segs)


def should_fuse(codec, cfg, like):
    """The ONE routing predicate for the fused dequant path (shared by
    fedfits.make_round and pod.make_train_step): int8 wire format,
    fused aggregation enabled, and every streaming block tiled by the
    quant block — anything else takes the decode-then-aggregate path."""
    if codec is None or codec.name != "int8":
        return False
    if not (getattr(cfg, "fused_agg", True)
            and getattr(cfg, "fused_dequant", True)):
        return False
    leaves = jax.tree_util.tree_leaves(like)
    c = leaves[0].shape[0]
    sizes = [int(l.size) // c for l in leaves]
    return fusable(sizes, c, getattr(cfg, "agg_blk", None), codec.qblk)


# ---------------------------------------------------------------------------
# pass 1: median reference + cosine-gate partials, from int8 codes
# ---------------------------------------------------------------------------

def _pass1_dq_body(n_ref, scale_ref, *refs, segs, total, c, qblk):
    L = len(segs)
    q_refs = refs[:L]
    s_refs = refs[L:2 * L]
    mask_ref = refs[2 * L]
    dot_ref, sqn_ref, refsq_ref = refs[2 * L + 1:]
    g = pl.program_id(0)
    i = pl.program_id(1)
    m = mask_ref[0].astype(jnp.float32)                  # (C, 1)
    n = n_ref[g].astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)
        refsq_ref[...] = jnp.zeros_like(refsq_ref)

    def accumulate(l, seg):
        x = _dq_block(q_refs, s_refs, l, seg, i, qblk)
        med = rp._median_block(x, m, n, c)
        s = scale_ref[l]
        dot_ref[...] += s * (x * med).sum(axis=1)[None, :]
        sqn_ref[...] += s * (x * x).sum(axis=1)[None, :]
        refsq_ref[...] += s * (med * med).sum(axis=1, keepdims=True)

    rp._foreach_active_leaf(segs, total, i, accumulate)


def dequant_gate_partials(q_leaves, s_leaves, mask, *, qblk, blk,
                          leaf_scale, interpret=False):
    """Segment-table pass 1 over int8 code leaves [(G, C, n_l)] + scale
    leaves [(G, C, nq_l)]: one ``pallas_call``, shared (C,) accumulators
    across all segments — the dequant happens in VMEM per block."""
    G, C = q_leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in q_leaves)
    segs, total = rp.make_segments(sizes, blk)
    n_sel = mask.sum(axis=1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, total),
        in_specs=[pl.BlockSpec((1, C, seg.blk), rp._seg_index_map(seg))
                  for seg in segs]
        + [pl.BlockSpec((1, C, seg.blk // qblk), rp._seg_index_map(seg))
           for seg in segs]
        + [pl.BlockSpec((1, C, 1), lambda g, i, *_: (g, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, C), lambda g, i, *_: (g, 0)),
            pl.BlockSpec((1, C), lambda g, i, *_: (g, 0)),
            pl.BlockSpec((1, 1), lambda g, i, *_: (g, 0)),
        ],
    )
    dots, sqn, refsq = pl.pallas_call(
        functools.partial(_pass1_dq_body, segs=segs, total=total, c=C,
                          qblk=qblk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(n_sel, leaf_scale, *q_leaves, *s_leaves, mask.reshape(G, C, 1))
    return dots, sqn, refsq


# ---------------------------------------------------------------------------
# pass 2: gated robust combine, from int8 codes
# ---------------------------------------------------------------------------

def _pass2_dq_body(n_ref, *refs, segs, total, c, qblk, mode, trim_frac):
    L = len(segs)
    q_refs = refs[:L]
    s_refs = refs[L:2 * L]
    m_ref, w_ref = refs[2 * L], refs[2 * L + 1]
    o_refs = refs[2 * L + 2:]
    g = pl.program_id(0)
    i = pl.program_id(1)
    m = m_ref[0].astype(jnp.float32)                     # (C, 1)
    w = w_ref[0].astype(jnp.float32)                     # (C, 1)
    n = n_ref[g].astype(jnp.float32)

    def emit(l, seg):
        x = _dq_block(q_refs, s_refs, l, seg, i, qblk)
        o_refs[l][0] = rp._combine_block(
            x, m, w, n, c=c, mode=mode, trim_frac=trim_frac
        ).astype(o_refs[l].dtype)

    rp._foreach_active_leaf(segs, total, i, emit)


def dequant_gated_combine(q_leaves, s_leaves, gated_mask, weights, *, qblk,
                          mode, trim_frac, blk, out_dtypes, interpret=False):
    """Segment-table pass 2 over int8 code leaves: per-leaf (G, n_l)
    outputs, each written in its own ``out_dtypes[l]``."""
    G, C = q_leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in q_leaves)
    segs, total = rp.make_segments(sizes, blk)
    n_sel = gated_mask.sum(axis=1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, total),
        in_specs=[pl.BlockSpec((1, C, seg.blk), rp._seg_index_map(seg))
                  for seg in segs]
        + [pl.BlockSpec((1, C, seg.blk // qblk), rp._seg_index_map(seg))
           for seg in segs]
        + [pl.BlockSpec((1, C, 1), lambda g, i, *_: (g, 0, 0)),
           pl.BlockSpec((1, C, 1), lambda g, i, *_: (g, 0, 0))],
        out_specs=[pl.BlockSpec((1, 1, seg.blk), rp._seg_index_map(seg))
                   for seg in segs],
    )
    outs = pl.pallas_call(
        functools.partial(_pass2_dq_body, segs=segs, total=total, c=C,
                          qblk=qblk, mode=mode, trim_frac=trim_frac),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((G, 1, seg.n), dt)
                   for seg, dt in zip(segs, out_dtypes)],
        interpret=interpret,
    )(n_sel, *q_leaves, *s_leaves, gated_mask.reshape(G, C, 1),
      weights.reshape(G, C, 1))
    return [o[:, 0] for o in outs]


# ---------------------------------------------------------------------------
# blocked pairwise distances (Krum), from int8 codes
# ---------------------------------------------------------------------------

def _pairwise_dq_body(scale_ref, *refs, segs, total, c, qblk):
    L = len(segs)
    q_refs = refs[:L]
    s_refs = refs[L:2 * L]
    gram_ref, sqn_ref = refs[2 * L:]
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)

    def accumulate(l, seg):
        x = _dq_block(q_refs, s_refs, l, seg, i, qblk)
        s = scale_ref[l]
        gram_ref[0] += s * jax.lax.dot_general(
            x, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sqn_ref[...] += s * (x * x).sum(axis=1)[None, :]

    rp._foreach_active_leaf(segs, total, i, accumulate)


def dequant_pairwise_sq_dists(q_leaves, s_leaves, mask, *, qblk, blk,
                              leaf_scale, interpret=False, axis_name=None):
    G, C = q_leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in q_leaves)
    segs, total = rp.make_segments(sizes, blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, total),
        in_specs=[pl.BlockSpec((1, C, seg.blk), rp._seg_index_map(seg))
                  for seg in segs]
        + [pl.BlockSpec((1, C, seg.blk // qblk), rp._seg_index_map(seg))
           for seg in segs],
        out_specs=[
            pl.BlockSpec((1, C, C), lambda g, i, *_: (g, 0, 0)),
            pl.BlockSpec((1, C), lambda g, i, *_: (g, 0)),
        ],
    )
    gram, sqn = pl.pallas_call(
        functools.partial(_pairwise_dq_body, segs=segs, total=total, c=C,
                          qblk=qblk),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, C, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
        ],
        interpret=interpret,
    )(leaf_scale, *q_leaves, *s_leaves)
    if axis_name is not None:
        gram = jax.lax.psum(gram, axis_name)
        sqn = jax.lax.psum(sqn, axis_name)
    d = sqn[:, :, None] + sqn[:, None, :] - 2.0 * gram
    big = rp._BIG * (1.0 - mask[:, :, None] * mask[:, None, :])
    return jnp.maximum(d, 0.0) + big


# ---------------------------------------------------------------------------
# the fused dequant pipeline
# ---------------------------------------------------------------------------

def fused_dequant_pipeline_leafwise(q_leaves, s_leaves, weights, mask, *,
                                    qblk, aggregator="trimmed_mean",
                                    trim_frac=0.2, cosine_thresh=-0.5,
                                    krum_f=1, krum_multi_m=1, blk=None,
                                    interpret=None, axis_name=None,
                                    leaf_scale=None, out_dtypes=None):
    """Full Eq.-11 pipeline over int8 code leaves [(G, C, n_l)] + scale
    leaves [(G, C, nq_l)] — same semantics, distribution hooks and
    return contract as ``robust_pipeline.fused_pipeline_leafwise`` on the
    decoded tree, without ever materialising it."""
    G, C = q_leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in q_leaves)
    if interpret is None:
        interpret = not rp._on_tpu()
    if blk is None:
        blk = rp.auto_blk(C, sizes)
    segs, _ = rp.make_segments(sizes, blk)
    assert all(seg.blk % qblk == 0 for seg in segs), \
        (qblk, [seg.blk for seg in segs])
    if leaf_scale is None:
        leaf_scale = jnp.ones((len(q_leaves),), jnp.float32)
    if out_dtypes is None:
        out_dtypes = [jnp.float32] * len(q_leaves)
    mask = mask.astype(jnp.float32)

    dots, sqn, refsq = dequant_gate_partials(
        q_leaves, s_leaves, mask, qblk=qblk, blk=blk, leaf_scale=leaf_scale,
        interpret=interpret)
    if axis_name is not None:
        dots = jax.lax.psum(dots, axis_name)
        sqn = jax.lax.psum(sqn, axis_name)
        refsq = jax.lax.psum(refsq, axis_name)

    m = rp._resolve_gate(dots, sqn, refsq, mask, cosine_thresh)

    combine = functools.partial(
        dequant_gated_combine, q_leaves, s_leaves, m, qblk=qblk, blk=blk,
        out_dtypes=out_dtypes, interpret=interpret)
    if aggregator == "fedavg":
        w = weights * m
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-12)
        return combine(w, mode="mean", trim_frac=trim_frac)
    if aggregator == "trimmed_mean":
        return combine(m, mode="trimmed", trim_frac=trim_frac)
    if aggregator == "median":
        return combine(m, mode="median", trim_frac=trim_frac)
    if aggregator == "krum":
        d = dequant_pairwise_sq_dists(
            q_leaves, s_leaves, m, qblk=qblk, blk=blk,
            leaf_scale=leaf_scale, interpret=interpret, axis_name=axis_name)
        w = rp._krum_weights(d, m, krum_f, krum_multi_m)
        return combine(w, mode="mean", trim_frac=trim_frac)
    raise ValueError(aggregator)


def _enc_views(enc, like):
    """Flatten an int8-encoded tree to ((1, C, n) code views,
    (1, C, nq) scale views, like-leaves, treedef)."""
    enc_leaves = jax.tree_util.tree_flatten(
        enc, is_leaf=codecs.is_encoded)[0]
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    C = enc_leaves[0].q.shape[0]
    q = [e.q.reshape(1, C, -1) for e in enc_leaves]
    s = [e.s.reshape(1, C, -1) for e in enc_leaves]
    return q, s, like_leaves, treedef


def fused_dequant_aggregate_tree(enc, weights, mask, cfg, *, like,
                                 blk=None, interpret=None):
    """Single-cohort Eq.-11 aggregation STRAIGHT from the int8 wire
    format: drop-in for ``aggregation.aggregate`` on the decoded tree
    (bit-identical to decode-then-fused-aggregate at the same ``blk``;
    within quantization error of the dense fp32 oracle).  ``like`` is
    the dense update pytree (arrays or ShapeDtypeStructs) defining the
    output shapes/dtypes.  Call under jit (the FL round functions are)."""
    qblk = getattr(cfg, "compress_qblk", 128)
    q, s, like_leaves, treedef = _enc_views(enc, like)
    outs = fused_dequant_pipeline_leafwise(
        q, s, weights[None], mask[None], qblk=qblk,
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk if blk is not None else getattr(cfg, "agg_blk", None),
        interpret=interpret,
        out_dtypes=[l.dtype for l in like_leaves])
    outs = [o[0].reshape(l.shape[1:]) for o, l in zip(outs, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, outs)


def fused_dequant_aggregate_sharded(enc, weights, mask, cfg, mesh, *, like,
                                    axes=None):
    """Mesh-sharded fused-dequant aggregation: the flattened int8 code
    axis shards over ``axes`` (default: every mesh axis but "pod") with
    its scale columns riding along; every device dequantizes and streams
    only its shard through both passes and one psum moves the (C,)
    cosine partials (+ Krum's Gram).  Leaves whose size does not divide
    ``extent * qblk`` stay replicated (de-duplicated by the 0/1 per-leaf
    scale) — the ``align=qblk`` condition keeps each shard's scale
    columns exactly aligned with its code columns."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding import specs as sh

    qblk = getattr(cfg, "compress_qblk", 128)
    if axes is None:
        axes = tuple(a for a in mesh.axis_names if a != "pod")
    axes = tuple(axes)
    q, s, like_leaves, treedef = _enc_views(enc, like)
    q_specs, shard_flags = sh.client_flat_specs(
        [f.shape[-1] for f in q], mesh, axes, align=qblk)
    s_specs = tuple(P(None, None, axes) if f else P(None, None, None)
                    for f in shard_flags)
    out_specs = tuple(P(None, axes) if f else P(None, None)
                      for f in shard_flags)
    # constrain codes AND scales before the boundary so the encoder's
    # outputs materialise in the (C, shard) layout (no boundary reshard,
    # same contract as the dense aggregate_sharded path)
    q = [jax.lax.with_sharding_constraint(f, NamedSharding(mesh, sp))
         for f, sp in zip(q, q_specs)]
    s = [jax.lax.with_sharding_constraint(f, NamedSharding(mesh, sp))
         for f, sp in zip(s, s_specs)]

    L = len(q)

    def agg(w, m, *flat):
        ql, sl = list(flat[:L]), list(flat[L:])
        own = jnp.float32(1.0)
        for a in axes:                                   # linear-index == 0
            own = own * (jax.lax.axis_index(a) == 0).astype(jnp.float32)
        scale = jnp.stack([jnp.float32(1.0) if f else own
                           for f in shard_flags])
        outs = fused_dequant_pipeline_leafwise(
            ql, sl, w[None], m[None], qblk=qblk,
            aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
            cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
            blk=getattr(cfg, "agg_blk", None),
            axis_name=axes, leaf_scale=scale,
            out_dtypes=[l.dtype for l in like_leaves])
        return tuple(outs)

    wrapped = shard_map(agg, mesh=mesh,
                        in_specs=(P(None), P(None)) + tuple(q_specs)
                        + tuple(s_specs),
                        out_specs=out_specs, check_rep=False)
    outs = wrapped(weights, mask, *q, *s)
    outs = [o.reshape(l.shape[1:]) for o, l in zip(outs, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, outs)
