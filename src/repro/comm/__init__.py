"""Compressed client->server transport subsystem.

  codecs.py                 uplink wire formats (int8/int4 blockwise
                            quant, 1-bit sign-SGD + majority vote,
                            top-k / random-k) + measured byte sizes
  error_feedback.py         per-client EF residual state (carried
                            through the ScanDriver donated carry)
  kernels/comm_codecs.py    fused dequant-into-aggregation Pallas
                            kernels (int8 codes stream straight into
                            the Eq.-11 robust pipeline)
"""
from repro.comm import codecs, error_feedback  # noqa: F401
