"""Client->server transport codecs — the compressed uplink wire formats.

Every update a client submits crosses the client->server boundary; at
cross-device scale (millions of users, SSIV communication complexity)
the uplink is the binding constraint, so the codecs here compress the
per-client update pytree into compact wire formats whose **measured**
byte counts drive the cost accounting in ``core/fedfits.py`` (no more
analytic ``2*|params|*4`` billing):

  int8      blockwise absmax quantization: 1 byte/coord + one f32 scale
            per ``qblk``-coordinate block per client (~3.9x at qblk=128)
  int4      same scheme at 4 bits, two codes packed per byte (~7.5x)
  signsgd   1-bit sign-SGD [Bernstein et al. 2018]: 8 signs/byte + the
            per-block mean-|x| magnitude; ``majority_vote`` implements
            the server-side majority-vote decode (~30x)
  topk      top-k sparsification: k = ceil(frac*n) largest-|x| coords
            as (int32 idx, f32 val) pairs; ``randk`` draws the k coords
            uniformly instead (unbiased, no magnitude pass)
  randk     the random-k fallback as its own codec (needs an rng)

All encode/decode paths are jit-able per leaf (static shapes; the only
data-dependent op is topk's ``lax.top_k``).  Encoded leaves are pytrees
(NamedTuples), so an encoded tree threads through ``lax.scan`` carries,
``shard_map`` and donation like any other state.  The int8 format is
additionally consumed *without decoding* by the fused dequant-into-
aggregation Pallas kernels in ``comm/kernels/comm_codecs.py``.

Compression error handling (EF residuals) lives in
``comm/error_feedback.py``; this module is purely the wire format.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class QuantLeaf(NamedTuple):
    """Blockwise-quantized leaf.

    q: (K, n) int8 codes (int4: (K, ceil(n/2)) with two codes per byte);
    s: (K, nq) f32 per-(client, quant-block) absmax scales, nq=ceil(n/qblk).
    """
    q: jnp.ndarray
    s: jnp.ndarray


class SignLeaf(NamedTuple):
    """1-bit sign-SGD leaf: bits (K, ceil(n/8)) uint8 packed signs
    (bit=1 -> +1), s (K, nq) f32 per-block mean-|x| magnitudes."""
    bits: jnp.ndarray
    s: jnp.ndarray


class SparseLeaf(NamedTuple):
    """Top-k / random-k leaf: idx (K, k) int32, val (K, k) f32."""
    idx: jnp.ndarray
    val: jnp.ndarray


ENC_TYPES = (QuantLeaf, SignLeaf, SparseLeaf)


def is_encoded(x) -> bool:
    return isinstance(x, ENC_TYPES)


def _flat2d(leaf):
    """(K, ...) leaf as a (K, n) view (reshape only, no copy)."""
    return leaf.reshape(leaf.shape[0], -1)


def _blocks(x2, qblk):
    """(K, n) f32 -> (K, nq, qblk) zero-padded quant-block view."""
    K, n = x2.shape
    nq = -(-n // qblk)
    xp = jnp.pad(x2.astype(jnp.float32), ((0, 0), (0, nq * qblk - n)))
    return xp.reshape(K, nq, qblk), nq


# ------------------------------------------------------------- int8/int4 --
def quant_encode(x2, qblk, levels):
    """Blockwise absmax quantization of a (K, n) matrix to
    ``levels``-level symmetric codes: q (K, n) int8 in [-levels, levels],
    s (K, nq) f32 scales.  dec = q * s[block] (the exact multiply the
    fused dequant kernel replays in VMEM — bit-identical)."""
    K, n = x2.shape
    b, nq = _blocks(x2, qblk)
    amax = jnp.max(jnp.abs(b), axis=2)                       # (K, nq)
    s = jnp.where(amax > 0, amax / levels, 1.0)
    q = jnp.clip(jnp.round(b / s[:, :, None]), -levels, levels)
    return q.reshape(K, nq * qblk)[:, :n].astype(jnp.int8), s


def quant_decode(q, s, n, qblk):
    """Inverse of ``quant_encode``: (K, n) f32 = q * s[block]."""
    K = q.shape[0]
    nq = s.shape[1]
    qp = jnp.pad(q, ((0, 0), (0, nq * qblk - n)))
    x = qp.astype(jnp.float32).reshape(K, nq, qblk) * s[:, :, None]
    return x.reshape(K, nq * qblk)[:, :n]


def pack_int4(q):
    """(K, n) int8 codes in [-7, 7] -> (K, ceil(n/2)) uint8, two 4-bit
    two's-complement nibbles per byte (low nibble = even coord)."""
    K, n = q.shape
    qp = jnp.pad(q, ((0, 0), (0, n % 2))).astype(jnp.uint8)
    lo = qp[:, 0::2] & 0x0F
    hi = (qp[:, 1::2] & 0x0F) << 4
    return lo | hi


def unpack_int4(p, n):
    """Inverse of ``pack_int4``: sign-extend both nibbles back to int8."""
    K = p.shape[0]
    lo = (p << 4).astype(jnp.int8) >> 4                       # low nibble
    hi = p.astype(jnp.int8) >> 4                              # high nibble
    return jnp.stack([lo, hi], axis=-1).reshape(K, -1)[:, :n]


# -------------------------------------------------------------- signsgd --
def pack_bits(b):
    """(K, n) 0/1 -> (K, ceil(n/8)) uint8, LSB-first."""
    K, n = b.shape
    bp = jnp.pad(b.astype(jnp.uint8), ((0, 0), (0, (-n) % 8)))
    w = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return (bp.reshape(K, -1, 8) * w).sum(-1).astype(jnp.uint8)


def unpack_bits(p, n):
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (p[:, :, None] >> shifts) & jnp.uint8(1)
    return bits.reshape(p.shape[0], -1)[:, :n]


def sign_encode(x2, qblk):
    """1-bit sign + per-block mean-|x| magnitude (scaled signSGD)."""
    K, n = x2.shape
    b, nq = _blocks(x2, qblk)
    # tail block averages over its REAL coords, not the zero padding
    cnts = jnp.full((nq,), float(qblk)).at[-1].set(
        float(n - (nq - 1) * qblk))
    s = jnp.abs(b).sum(-1) / cnts
    bits = pack_bits((x2 >= 0).astype(jnp.uint8))
    return bits, s


def sign_decode(bits, s, n, qblk):
    """Per-client decode: sign * per-block mean magnitude."""
    K = bits.shape[0]
    sg = unpack_bits(bits, n).astype(jnp.float32) * 2.0 - 1.0
    nq = s.shape[1]
    sp = jnp.pad(sg, ((0, 0), (0, nq * qblk - n)))
    x = sp.reshape(K, nq, qblk) * s[:, :, None]
    return x.reshape(K, nq * qblk)[:, :n]


def majority_vote(enc: SignLeaf, n, qblk, mask, weights=None):
    """Server-side majority-vote decode of a cohort of sign-SGD leaves:
    per-coordinate (optionally weighted) vote over masked-in clients,
    scaled by the masked mean of the clients' block magnitudes.  Returns
    ONE (n,) aggregate row (the signSGD-with-majority-vote server rule
    [Bernstein et al. 2019]); the per-client ``sign_decode`` path is what
    feeds the robust aggregation gate instead."""
    sg = unpack_bits(enc.bits, n).astype(jnp.float32) * 2.0 - 1.0
    w = mask if weights is None else weights * mask
    vote = jnp.sign(jnp.tensordot(w, sg, axes=(0, 0)))
    ms = jnp.tensordot(mask, enc.s, axes=(0, 0)) \
        / jnp.maximum(mask.sum(), 1.0)                        # (nq,)
    scale = jnp.repeat(ms, qblk)[:n]
    return vote * scale


# ---------------------------------------------------------------- top-k --
def topk_encode(x2, k):
    _, idx = jax.lax.top_k(jnp.abs(x2), k)
    val = jnp.take_along_axis(x2.astype(jnp.float32), idx, axis=1)
    return idx.astype(jnp.int32), val


def randk_encode(x2, k, rng):
    K, n = x2.shape
    keys = jax.random.split(rng, K)
    idx = jax.vmap(
        lambda kk: jax.random.permutation(kk, n)[:k])(keys).astype(jnp.int32)
    val = jnp.take_along_axis(x2.astype(jnp.float32), idx, axis=1)
    return idx, val


def sparse_decode(idx, val, n):
    def one(i, v):
        return jnp.zeros((n,), jnp.float32).at[i].set(v)

    return jax.vmap(one)(idx, val)


# ------------------------------------------------------------ the codec --
@dataclasses.dataclass(frozen=True)
class Codec:
    """One wire format.  Frozen/hashable so it can close over jitted
    round functions and ride static jit arguments."""
    name: str                          # int8|int4|signsgd|topk|randk
    qblk: int = 128                    # quant-block width (per-block scales)
    topk_frac: float = 0.05            # top-k kept fraction of each leaf

    @property
    def stochastic(self) -> bool:
        return self.name == "randk"

    def _k(self, n):
        """Kept coords per leaf: ceil(frac * n), clamped to [1, n]."""
        return max(1, min(int(n), math.ceil(self.topk_frac * int(n))))

    # ---- per-leaf ----------------------------------------------------
    def encode(self, leaf, rng=None):
        x2 = _flat2d(leaf)
        n = x2.shape[1]
        if self.name == "int8":
            q, s = quant_encode(x2, self.qblk, 127.0)
            return QuantLeaf(q, s)
        if self.name == "int4":
            q, s = quant_encode(x2, self.qblk, 7.0)
            return QuantLeaf(pack_int4(q), s)
        if self.name == "signsgd":
            bits, s = sign_encode(x2, self.qblk)
            return SignLeaf(bits, s)
        if self.name == "topk":
            return SparseLeaf(*topk_encode(x2, self._k(n)))
        if self.name == "randk":
            # the random-k fallback: same sparse wire format, indices
            # drawn uniformly (unbiased, no magnitude ranking pass)
            if rng is None:
                raise ValueError("randk codec needs an rng at encode time")
            return SparseLeaf(*randk_encode(x2, self._k(n), rng))
        raise ValueError(self.name)

    def decode(self, enc, like):
        """Decode one encoded leaf back to ``like``'s shape/dtype.
        ``like`` may be an array or a ShapeDtypeStruct."""
        shape, dtype = like.shape, like.dtype
        n = 1
        for d in shape[1:]:
            n *= d
        if self.name == "int8":
            x = quant_decode(enc.q, enc.s, n, self.qblk)
        elif self.name == "int4":
            x = quant_decode(unpack_int4(enc.q, n), enc.s, n, self.qblk)
        elif self.name == "signsgd":
            x = sign_decode(enc.bits, enc.s, n, self.qblk)
        elif self.name == "topk":
            x = sparse_decode(enc.idx, enc.val, n)
        elif self.name == "randk":
            # importance-scale by n/k so the estimator is UNBIASED over
            # the uniform index draw (E[dec] = x); top-k keeps raw values
            # (biased by construction — EF mops up the dropped mass)
            k = enc.val.shape[1]
            x = sparse_decode(enc.idx, enc.val, n) * (n / k)
        else:
            raise ValueError(self.name)
        return x.reshape(shape).astype(dtype)

    # ---- pytrees -----------------------------------------------------
    def encode_tree(self, tree, rng=None):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if self.stochastic:
            if rng is None:
                raise ValueError("randk codec needs an rng at encode time")
            keys = list(jax.random.split(rng, len(leaves)))
        else:
            keys = [None] * len(leaves)
        return jax.tree_util.tree_unflatten(
            treedef, [self.encode(l, k) for l, k in zip(leaves, keys)])

    def decode_tree(self, enc, like):
        enc_leaves = jax.tree_util.tree_flatten(enc, is_leaf=is_encoded)[0]
        like_leaves, treedef = jax.tree_util.tree_flatten(like)
        return jax.tree_util.tree_unflatten(
            treedef, [self.decode(e, l)
                      for e, l in zip(enc_leaves, like_leaves)])


def make_codec(cfg) -> Optional[Codec]:
    """Build the configured codec from a FedConfig (None when off)."""
    name = getattr(cfg, "compress", "none") or "none"
    if name == "none":
        return None
    return Codec(name=name, qblk=getattr(cfg, "compress_qblk", 128),
                 topk_frac=getattr(cfg, "compress_topk_frac", 0.05))


# ---------------------------------------------------- measured byte sizes --
def wire_bytes_per_client(enc_tree) -> float:
    """MEASURED uplink bytes one client's encoded update occupies on the
    wire: summed over every array of the encoded pytree (codes, scales,
    indices — all of it), from the actual dtypes and shapes.  Static at
    trace time (shape/dtype only), so it folds into jitted accounting."""
    arrs = jax.tree_util.tree_leaves(enc_tree)
    k = arrs[0].shape[0]
    return float(sum(a.size * jnp.dtype(a.dtype).itemsize
                     for a in arrs)) / float(k)


def dense_bytes_per_client(tree) -> float:
    """Uncompressed uplink bytes per client for a (K, ...) update pytree,
    from the actual leaf dtype itemsizes (bf16 leaves are 2 bytes, not
    the analytic model's flat 4)."""
    leaves = jax.tree_util.tree_leaves(tree)
    k = leaves[0].shape[0]
    return float(sum(l.size * jnp.dtype(l.dtype).itemsize
                     for l in leaves)) / float(k)


def param_bytes(params) -> float:
    """Downlink bytes of one dense global-model broadcast, from actual
    leaf dtype itemsizes."""
    return float(sum(l.size * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree_util.tree_leaves(params)))
