"""Error-feedback (EF) residual state for the compressed uplink.

Biased codecs (quantization, top-k) drop part of every update; EF
[Seide et al. 2014; Karimireddy et al. 2019 "EF-SGD"] keeps the dropped
part as a per-client residual and re-injects it into the NEXT round's
update before encoding:

    target_t   = update_t + residual_{t-1}
    wire_t     = encode(target_t)
    residual_t = target_t - decode(wire_t)

so the compression error telescopes instead of accumulating — the sum of
decoded updates tracks the sum of true updates to within one residual.
The residual lives CLIENT-side (each client knows exactly what it sent),
so it adds no wire traffic; in the simulation it is a (K, ...) pytree
carried through the ``ScanDriver`` donated carry as ``FedState.ef`` /
``PodFedState.ef`` — zero host round-trips, updated in place.

``compress`` is the one-call client boundary: EF inject -> encode ->
decode -> residual update.  The decode it returns is what a dense-path
server would aggregate; the int8 fused-dequant server path aggregates
the ENCODED form directly (bit-identical — see
``comm/kernels/comm_codecs.py``) and still uses the same residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(updates_like):
    """Zero residual state matching a (K, ...) update pytree (arrays or
    ShapeDtypeStructs)."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, l.dtype), updates_like)


def compress(codec, updates, residual=None, rng=None):
    """One client->server boundary crossing.

    Returns ``(enc, dec, new_residual)``: the encoded wire pytree, its
    decode (what the server's dense path aggregates), and the updated
    EF residual (``None`` in, ``None`` out = EF disabled)."""
    if residual is not None:
        target = jax.tree_util.tree_map(
            lambda u, r: u + r.astype(u.dtype), updates, residual)
    else:
        target = updates
    enc = codec.encode_tree(target, rng=rng)
    dec = codec.decode_tree(enc, updates)
    if residual is None:
        return enc, dec, None
    new_residual = jax.tree_util.tree_map(
        lambda t, d: (t - d).astype(t.dtype), target, dec)
    return enc, dec, new_residual
