"""Serving subsystem (ROADMAP item 3): continuous batching over a paged
KV cache with a Pallas flash-decode kernel and optional int8 KV.

  scheduler.py  slot protocol, page allocation, ServeConfig/SlotState,
                the HostLedger admission mirror
  engine.py     jitted admit/decode programs + the host serving loop

Kernels live in repro.kernels.paged_decode{,_ref}; the attention-layer
cache plumbing is models/attention.py's paged branch.
"""
from repro.serve.engine import ServeEngine, init_paged_cache, kv_bytes_read
from repro.serve.scheduler import (HostLedger, Request, ServeConfig,
                                   SlotState)

__all__ = ["ServeEngine", "ServeConfig", "SlotState", "Request",
           "HostLedger", "init_paged_cache", "kv_bytes_read"]
