"""Continuous-batching serving engine: paged KV + slotted decode.

One jitted **decode step** (donated cache pools + slot state) runs the
whole fleet of slots forever; one jitted **admit step** prefills a
request into a freshly allocated page run and samples its first token.
The host loop between steps is pure bookkeeping: drain the step's small
output dict, attribute tokens to requests, admit from the pending queue
while the :class:`~repro.serve.scheduler.HostLedger` says a slot + pages
are free.

Cache layout: the engine's master cache holds ONLY the page pools
(``kp``/``vp`` and the int8 ``ks``/``vs`` scales), stacked with the
transformer's n_units-leading layer scan axis.  The scheduler context
(page table, lengths, active mask) lives in :class:`SlotState` and is
broadcast into the per-call cache view (``_with_ctx``) — so the donated
pools alias in place while the tiny context rides the slot carry.

``run(requests, continuous=False)`` is the fixed-batch baseline for the
BENCH comparison: identical admit/decode programs, but admission only
happens when every slot is empty (classic batch-until-slowest-finishes
serving).  Scheduling is therefore the only variable between the two
rows.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import transformer
from repro.obs import counters as obs_counters
from repro.serve import scheduler as sched
from repro.serve.scheduler import (HostLedger, Request, ServeConfig,
                                   SlotState)

POOL_KEYS = ("kp", "vp", "ks", "vs")
CTX_KEYS = ("table", "length", "active", "new_valid")


def init_paged_cache(cfg, scfg: ServeConfig):
    """Stacked page pools for the layer scan (pools only — the
    scheduler context is injected per call by _with_ctx)."""
    cycle, n_units = transformer.layer_cycle(cfg)
    if any(k not in ("attn", "moe") for k in cycle):
        raise ValueError(
            "paged serving supports homogeneous attn/moe stacks, got "
            f"{cycle}")
    one = attn_lib.init_paged_kv_cache(
        cfg, scfg.max_slots, scfg.total_pages, scfg.page_size,
        scfg.pages_per_slot, int8=scfg.kv_int8, dtype=jnp.float32)
    unit = {f"b{i}": {k: v for k, v in one.items() if k in POOL_KEYS}
            for i in range(len(cycle))}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape), unit)


def _with_ctx(pools, table, length, active, new_valid):
    """Cache view for one forward call: pools + scheduler context
    replicated across the stacked layer units."""
    ctx = {"table": table, "length": length, "active": active,
           "new_valid": new_valid}
    out = {}
    for name, block in pools.items():
        n_units = block["kp"].shape[0]
        b = dict(block)
        for k, v in ctx.items():
            b[k] = jnp.broadcast_to(v[None], (n_units,) + v.shape)
        out[name] = b
    return out


def _strip_ctx(cache):
    """Master cache back out of a forward's returned cache: pools only
    (the context echo is stale by design — SlotState owns it)."""
    return {name: {k: v for k, v in block.items() if k in POOL_KEYS}
            for name, block in cache.items()}


def kv_bytes_read(cfg, scfg: ServeConfig, pages_in_use: float) -> float:
    """KV bytes one decode step streams from the pools (all layers):
    live pages x rows x heads x head-dim x itemsize x {k, v}, plus the
    f32 scale planes on the int8 path.  This is the measured-bytes
    mirror of the BENCH serve rows."""
    cycle, n_units = transformer.layer_cycle(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    rows = pages_in_use * scfg.page_size
    item = 1 if scfg.kv_int8 else 4
    per_layer = 2.0 * rows * hkv * (dh * item + (4 if scfg.kv_int8 else 0))
    return per_layer * n_units * len(cycle)


class ServeEngine:
    """Builds the jitted admit/decode programs and drives the loop."""

    def __init__(self, cfg, scfg: ServeConfig, params, *, seed: int = 0):
        self.cfg = cfg.replace(
            attn_impl="pallas" if scfg.attn == "pallas" else "xla")
        self.scfg = scfg
        self.params = params
        self.seed = seed
        self._decode = jax.jit(self._make_decode(), donate_argnums=(1, 2))
        self._admit = jax.jit(self._make_admit(), donate_argnums=(1, 2))

    # -- state ---------------------------------------------------------
    def fresh_state(self) -> Tuple[dict, SlotState]:
        cache = init_paged_cache(self.cfg, self.scfg)
        st = sched.init_slot_state(
            self.scfg, jax.random.PRNGKey(self.seed),
            obs_counters.init_column("serve", None))
        return cache, st

    # -- jitted decode step -------------------------------------------
    def _make_decode(self):
        cfg, scfg = self.cfg, self.scfg
        s, n, maxp = scfg.max_slots, scfg.total_pages, scfg.pages_per_slot

        def decode(params, pools, st: SlotState):
            key, sub = jax.random.split(st.key)
            view = _with_ctx(pools, st.table, st.length, st.active,
                             jnp.zeros((s,), jnp.int32))
            logits, new_cache, _ = transformer.forward(
                params, cfg, tokens=st.tok,
                positions=st.length[:, None], cache=view)
            lg = logits[:, 0]
            if scfg.temperature > 0:
                nxt = jax.random.categorical(sub, lg / scfg.temperature)
            else:
                nxt = jnp.argmax(lg, -1)
            nxt = nxt.astype(jnp.int32)
            act = st.active
            emitted = act
            new_len = st.length + (act > 0).astype(jnp.int32)
            done = (act > 0) & ((new_len >= st.budget)
                                | (nxt == scfg.eos_id))
            done_f = done.astype(jnp.float32)
            owned = (jnp.arange(maxp)[None, :] < st.alloc[:, None]) \
                & done[:, None]
            dest = jnp.where(owned, st.table, n).reshape(-1)
            free = st.free.at[dest].set(1.0, mode="drop")
            new_active = act * (1.0 - done_f)
            vals = {
                "serve/slot_occupancy": new_active.sum(),
                "serve/admitted": jnp.float32(0.0),
                "serve/evicted": done_f.sum(),
                "serve/tokens": act.sum(),
                "serve/pages_in_use": n - free.sum(),
                "serve/tokens_per_s": jnp.float32(0.0),
            }
            st2 = st._replace(
                tok=nxt[:, None], length=new_len, active=new_active,
                alloc=jnp.where(done, 0, st.alloc), free=free,
                tele=obs_counters.accumulate(st.tele, vals, "serve"),
                key=key)
            out = {"next": nxt, "emitted": emitted, "finished": done_f,
                   "req": st.req_id, "vals": vals}
            return _strip_ctx(new_cache), st2, out

        return decode

    # -- jitted admit step --------------------------------------------
    def _make_admit(self):
        cfg, scfg = self.cfg, self.scfg
        s, n, maxp = scfg.max_slots, scfg.total_pages, scfg.pages_per_slot
        pmax = scfg.prompt_pad

        def admit(params, pools, st: SlotState, prompt, plen, max_new,
                  req_id):
            key, sub = jax.random.split(st.key)
            slot, has_slot = sched.pick_free_slot(st.active)
            budget = jnp.minimum(plen + max_new - 1, scfg.max_len)
            need = (budget + scfg.page_size - 1) // scfg.page_size
            pages, fits, free2 = sched.take_pages(st.free, need, maxp)
            ok = has_slot & fits
            live = ok & (max_new >= 2)
            # a max_new=1 request completes at admission: its transient
            # pages go straight back (stale rows are safe — appends
            # overwrite before any mask exposes them)
            free3 = jnp.where(live, free2, st.free)
            row = jnp.where(ok, pages, 0)
            view = _with_ctx(pools, row[None],
                             jnp.zeros((1,), jnp.int32),
                             jnp.ones((1,), jnp.float32),
                             jnp.where(ok, plen, 0)[None])
            hidden, new_cache, _ = transformer.forward(
                params, cfg, tokens=prompt[None],
                positions=jnp.arange(pmax)[None], cache=view,
                collect_logits=False)
            h = jnp.take(hidden[0], plen - 1, axis=0)
            lg = transformer.lm_head(params, cfg, h[None, None])[0, 0]
            if scfg.temperature > 0:
                tok0 = jax.random.categorical(sub, lg / scfg.temperature)
            else:
                tok0 = jnp.argmax(lg, -1)
            tok0 = tok0.astype(jnp.int32)
            sl = jnp.where(ok, slot, s)            # s = drop row
            live_f = live.astype(jnp.float32)
            active2 = st.active.at[sl].set(live_f, mode="drop")
            vals = {
                "serve/slot_occupancy": active2.sum(),
                "serve/admitted": ok.astype(jnp.float32),
                "serve/evicted": ok.astype(jnp.float32) * (1.0 - live_f),
                "serve/tokens": ok.astype(jnp.float32),
                "serve/pages_in_use": n - free3.sum(),
                "serve/tokens_per_s": jnp.float32(0.0),
            }
            st2 = st._replace(
                tok=st.tok.at[sl].set(tok0[None], mode="drop"),
                length=st.length.at[sl].set(plen, mode="drop"),
                budget=st.budget.at[sl].set(budget, mode="drop"),
                active=active2,
                req_id=st.req_id.at[sl].set(req_id, mode="drop"),
                alloc=st.alloc.at[sl].set(jnp.where(live, need, 0),
                                          mode="drop"),
                table=st.table.at[sl].set(row, mode="drop"),
                free=free3,
                tele=obs_counters.accumulate(st.tele, vals, "serve"),
                key=key)
            out = {"ok": ok, "slot": slot, "tok0": tok0, "vals": vals}
            return _strip_ctx(new_cache), st2, out

        return admit

    # -- host loop -----------------------------------------------------
    def run(self, requests: Sequence[Request], *, telemetry=None,
            continuous: bool = True) -> Tuple[Dict[int, List[int]], dict]:
        """Serve ``requests``; returns ({req_id: tokens}, stats).

        continuous=True: admit whenever a slot + pages free up (the
        tentpole path).  continuous=False: fixed-batch baseline — admit
        only into an all-empty fleet, then decode until every slot
        drains (identical compiled programs, scheduling is the only
        difference)."""
        scfg = self.scfg
        for r in requests:
            sched.validate_request(r, scfg)
        if telemetry is not None:
            telemetry.bind_engine("serve")
        ledger = HostLedger(scfg)
        pending = list(requests)
        cache, st = self.fresh_state()
        results: Dict[int, List[int]] = {r.req_id: [] for r in requests}
        occupancy_trail: List[int] = []
        steps = 0
        total_emitted = 0
        admitted_since = 0
        t0 = time.perf_counter()
        while pending or ledger.n_active > 0:
            group_open = ledger.n_active == 0
            while pending:
                r = pending[0]
                need = sched.pages_needed(len(r.tokens), r.max_new, scfg)
                if not ledger.can_admit(need):
                    break
                if not continuous and not group_open:
                    break
                pending.pop(0)
                want_slot = ledger.next_slot()
                prompt = jnp.zeros((scfg.prompt_pad,), jnp.int32) \
                    .at[:len(r.tokens)].set(jnp.asarray(r.tokens,
                                                        jnp.int32))
                cache, st, out = self._admit(
                    self.params, cache, st, prompt,
                    jnp.int32(len(r.tokens)), jnp.int32(r.max_new),
                    jnp.int32(r.req_id))
                out = jax.device_get(out)
                if not bool(out["ok"]) or int(out["slot"]) != want_slot:
                    raise RuntimeError(
                        f"scheduler mirror diverged on req {r.req_id}: "
                        f"device ok={bool(out['ok'])} "
                        f"slot={int(out['slot'])}, host slot={want_slot}")
                results[r.req_id].append(int(out["tok0"]))
                total_emitted += 1
                admitted_since += 1
                if r.max_new >= 2:
                    ledger.admit_at(want_slot, need)
            if ledger.n_active == 0:
                if pending:
                    raise RuntimeError("scheduler stalled with pending "
                                       "requests (pool too small?)")
                break
            w0 = telemetry.now_us() if telemetry is not None else 0.0
            ts = time.perf_counter()
            cache, st, out = self._decode(self.params, cache, st)
            out = jax.device_get(out)
            dt = time.perf_counter() - ts
            steps += 1
            ntok = 0
            for i in range(scfg.max_slots):
                if out["emitted"][i] > 0:
                    results[int(out["req"][i])].append(int(out["next"][i]))
                    ntok += 1
                if out["finished"][i] > 0:
                    ledger.evict(i)
            total_emitted += ntok
            occupancy_trail.append(int(out["vals"]["serve/slot_occupancy"]))
            if telemetry is not None:
                row = {"round": steps}
                row.update({obs_counters.METRIC_PREFIX + k: float(v)
                            for k, v in out["vals"].items()})
                row[obs_counters.METRIC_PREFIX + "serve/admitted"] = \
                    float(admitted_since)
                row[obs_counters.METRIC_PREFIX + "serve/tokens_per_s"] = \
                    ntok / max(dt, 1e-9)
                telemetry.observe_rows([row], w0,
                                       telemetry.now_us() - w0,
                                       measured=True, phases=False)
            admitted_since = 0
        wall = time.perf_counter() - t0
        stats = {
            "engine": "continuous" if continuous else "fixed",
            "steps": steps,
            "tokens": total_emitted,
            "wall_s": wall,
            "tokens_per_s": total_emitted / max(wall, 1e-9),
            "occupancy_trail": occupancy_trail,
            "free_pages_end": ledger.free_pages,
        }
        return results, stats
