"""Slot-based request scheduler for continuous batching (ROADMAP item 3).

Slot protocol
-------------
* A request occupies exactly one decode slot from admission to
  eviction; the decode batch is always ``(max_slots, 1)`` — no dynamic
  shapes, one compiled decode step reused forever.
* Admission picks the first free slot by the integer-key argsort idiom
  the async DeliveryBuffer uses (core/async_engine.py): free slots keep
  their index as the sort key, occupied slots sort after every free one.
* ALL pages a request can ever need — ``ceil(budget / page_size)`` with
  ``budget = min(plen + max_new - 1, max_len)`` KV rows — are allocated
  at admission.  That makes the scheduler exhaustion-free by
  construction (an admitted request can always finish), keeps every
  shape static, and still buys the paged wins: slots share one pool,
  eviction frees pages with a masked scatter (no compaction copy), and
  short requests return their pages the step they finish.
* Eviction happens inside the decode step: a slot whose new KV length
  reaches its budget (or that sampled ``eos_id``) flips inactive and its
  pages scatter back into the free mask — the next admission reuses
  them without any copy.

Token accounting: the first generated token is sampled from the prefill
logits at admission, so a request emits ``1 + (budget - plen)`` tokens
total = ``max_new`` (when not truncated by ``max_len``).  ``max_new = 1``
requests complete at admission and never occupy a slot.

:class:`HostLedger` is the host-side mirror of the device scheduler:
admission decisions (slot choice, page availability) are pure functions
of the admit/evict history, so the host can decide *whether* to admit
without a device sync, and the device ``ok`` flag only asserts
agreement.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving shapes + policy knobs (all jit-constants)."""
    max_slots: int = 8          # decode batch width
    page_size: int = 16         # KV rows per page
    max_len: int = 256          # per-request KV row cap (prompt + gen)
    prompt_pad: int = 32        # static prefill width (prompts padded)
    num_pages: int = 0          # pool size; 0 -> worst-case full budget
    eos_id: int = -1            # sampled token that evicts; -1 = never
    temperature: float = 0.0    # 0 = argmax decoding
    kv_int8: bool = False       # int8 page pools + per-row scales
    attn: str = "ref"           # ref | pallas (paged flash-decode)

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def total_pages(self) -> int:
        return self.num_pages or self.max_slots * self.pages_per_slot


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    tokens: Tuple[int, ...]     # prompt token ids (1 <= len <= prompt_pad)
    max_new: int                # tokens to generate (incl. the admit token)


class SlotState(NamedTuple):
    """Per-slot device state riding the donated decode carry."""
    tok: jnp.ndarray        # (S, 1) i32   last emitted token per slot
    length: jnp.ndarray     # (S,)   i32   valid KV rows per slot
    budget: jnp.ndarray     # (S,)   i32   KV length at which the slot ends
    active: jnp.ndarray     # (S,)   f32   1 = live request
    req_id: jnp.ndarray     # (S,)   i32   owning request (attribution)
    alloc: jnp.ndarray      # (S,)   i32   pages owned by the slot
    table: jnp.ndarray      # (S, maxp) i32  page table
    free: jnp.ndarray       # (N,)   f32   free-page mask over the pool
    tele: dict              # obs counter column (serve/* registry slice)
    key: jnp.ndarray        # PRNG carry (split every step)


def init_slot_state(scfg: ServeConfig, key, tele) -> SlotState:
    s, maxp, n = scfg.max_slots, scfg.pages_per_slot, scfg.total_pages
    return SlotState(
        tok=jnp.zeros((s, 1), jnp.int32),
        length=jnp.zeros((s,), jnp.int32),
        budget=jnp.zeros((s,), jnp.int32),
        active=jnp.zeros((s,), jnp.float32),
        req_id=jnp.full((s,), -1, jnp.int32),
        alloc=jnp.zeros((s,), jnp.int32),
        table=jnp.zeros((s, maxp), jnp.int32),
        free=jnp.ones((n,), jnp.float32),
        tele=tele, key=key)


def kv_budget(plen: int, max_new: int, scfg: ServeConfig) -> int:
    """KV rows a request can occupy (host-side mirror of the device
    arithmetic in the admit step)."""
    return min(plen + max_new - 1, scfg.max_len)


def pages_needed(plen: int, max_new: int, scfg: ServeConfig) -> int:
    return -(-kv_budget(plen, max_new, scfg) // scfg.page_size)


def pick_free_slot(active):
    """First inactive slot by integer-key argsort (DeliveryBuffer
    idiom); (slot, has_slot)."""
    s = active.shape[0]
    idx = jnp.arange(s)
    order = jnp.argsort(jnp.where(active > 0, s + idx, idx))
    return order[0], active.sum() < s


def take_pages(free, need, maxp):
    """Claim ``need`` pages from the free mask: returns a (maxp,) page
    row (unused tail = 0), the feasibility flag, and the updated mask.
    Nothing is taken when infeasible."""
    n = free.shape[0]
    idx = jnp.arange(n)
    order = jnp.argsort(jnp.where(free > 0, idx, n + idx))
    ok = need <= free.sum()
    j = jnp.arange(maxp)
    takes = (j < need) & ok
    pages = jnp.where(takes, order[jnp.clip(j, 0, n - 1)], 0)
    free2 = free.at[jnp.where(takes, pages, n)].set(0.0, mode="drop")
    return pages, ok, free2


def validate_request(r: Request, scfg: ServeConfig) -> None:
    plen = len(r.tokens)
    if not 1 <= plen <= scfg.prompt_pad:
        raise ValueError(f"req {r.req_id}: prompt length {plen} outside "
                         f"[1, prompt_pad={scfg.prompt_pad}]")
    if plen > scfg.max_len:
        raise ValueError(f"req {r.req_id}: prompt longer than max_len")
    if r.max_new < 1:
        raise ValueError(f"req {r.req_id}: max_new must be >= 1")
    if pages_needed(plen, r.max_new, scfg) > scfg.total_pages:
        raise ValueError(f"req {r.req_id}: needs more pages than the pool")


class HostLedger:
    """Host mirror of the device scheduler's admit/evict bookkeeping.

    The device admit step is deterministic given the admit/evict
    history (first free slot, first free pages), so the host replays
    the same arithmetic to decide *whether* the next request fits —
    no device sync on the admission path.  The engine asserts the
    device ``ok``/slot agree with the mirror on every admit.
    """

    def __init__(self, scfg: ServeConfig):
        self.scfg = scfg
        self.free_pages = scfg.total_pages
        self.slot_pages = [0] * scfg.max_slots
        self.active = [False] * scfg.max_slots

    @property
    def n_active(self) -> int:
        return sum(self.active)

    def can_admit(self, need: int) -> bool:
        return (not all(self.active)) and need <= self.free_pages

    def next_slot(self) -> int:
        return self.active.index(False)

    def admit_at(self, slot: int, need: int) -> None:
        assert not self.active[slot] and need <= self.free_pages
        self.active[slot] = True
        self.slot_pages[slot] = need
        self.free_pages -= need

    def evict(self, slot: int) -> None:
        assert self.active[slot]
        self.active[slot] = False
        self.free_pages += self.slot_pages[slot]
        self.slot_pages[slot] = 0
