"""Partition-spec rules: name-based mapping from parameter paths to
PartitionSpecs over the ("data", "model") (+ optional "pod") mesh.

Sharding scheme (MaxText-style FSDP x TP + FL semantics):
  * batch            -> ("pod","data") axes (clients are data-axis groups)
  * weights          -> 2D-sharded: one dim over "model" (tensor/expert
    parallel), the other over "data" (FSDP; GSPMD inserts the per-layer
    all-gather). Without the FSDP leg, 72B fp32 params at 1/16 would be
    18 GB/chip — over the v5e budget. Params stay pod-replicated (grads
    all-reduce over "pod" = the cross-slot aggregation leg).
  * embeddings       -> vocab over "model", d_model over "data"
  * KV caches        -> batch over data, *sequence* over "model" (kv-head
    counts (5, 8) don't divide the 16-way model axis; sequence does)
  * small/recurrent leaves (norms, gates, biases, sLSTM recurrence)
    replicated

Layer params carry a leading stacked n_units axis -> specs get a leading
None.
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# (regex over the param path, spec for the *unstacked* leaf)
_RULES = [
    # embeddings / head
    (r"(^|/)embed$", lambda nd: P("model", "data")),
    (r"(^|/)lm_head$", lambda nd: P("data", "model")),
    # attention
    (r"attn/w[qkv]$|xattn/w[qkv]$", lambda nd: P("data", "model")),
    (r"attn/wo$|xattn/wo$", lambda nd: P("model", "data")),
    (r"attn/b[qkv]$|xattn/b[qkv]$", lambda nd: P("model")),
    # dense mlp
    (r"mlp/w[gu]$", lambda nd: P("data", "model")),
    (r"mlp/wo$", lambda nd: P("model", "data")),
    # moe (expert-parallel over "model", FSDP over "data")
    (r"moe/router$", lambda nd: P(None, None)),
    (r"moe/w[guo]$", lambda nd: P("model", "data", None)),
    # mamba (d_inner over "model")
    (r"mamba/in_proj$", lambda nd: P("data", "model")),
    (r"mamba/conv_w$", lambda nd: P(None, "model")),
    (r"mamba/conv_b$|mamba/dt_bias$|mamba/D$", lambda nd: P("model")),
    (r"mamba/x_proj$|mamba/out_proj$|mamba/A_log$", lambda nd: P("model", None)),
    (r"mamba/dt_proj$", lambda nd: P(None, "model")),
    # mlstm (d_inner over "model"; tiny gate/norm leaves replicated)
    (r"mlstm/up$", lambda nd: P("data", "model")),
    (r"mlstm/w[qkv]$", lambda nd: P("data", "model")),
    (r"mlstm/conv_w$", lambda nd: P(None, "model")),
    (r"mlstm/conv_b$|mlstm/gn$", lambda nd: P("model")),
    (r"mlstm/down$", lambda nd: P("model", "data")),
    # slstm
    (r"slstm/w$", lambda nd: P("data", "model")),
    (r"slstm/up_[gu]$", lambda nd: P("data", "model")),
    (r"slstm/down$", lambda nd: P("model", "data")),
]


def _path_str(path):
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_specs(params: Any, *, mesh=None) -> Any:
    """PartitionSpec pytree matching ``params`` (any pytree containing a
    params subtree — opt states / PodState included, the path rules match
    on suffixes). If ``mesh`` is given, any sharded dim that does not
    divide its mesh-axis extent falls back to replicated (safety net)."""

    def spec_for(path, leaf):
        s = _path_str(path)
        stacked = re.search(r"(^|/)layers/", s) is not None
        for pat, fn in _RULES:
            if re.search(pat, s):
                base = fn(leaf.ndim - (1 if stacked else 0))
                parts = tuple(base)
                if stacked:
                    parts = (None,) + parts
                # pad/truncate to leaf rank
                parts = parts[: leaf.ndim]
                parts = parts + (None,) * (leaf.ndim - len(parts))
                if mesh is not None:
                    parts = tuple(
                        a if (a is None or leaf.shape[i] %
                              _axis_size(mesh, a) == 0) else None
                        for i, a in enumerate(parts))
                return P(*parts)
        return P(*([None] * leaf.ndim))     # replicate by default

    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_specs_moe_ff(params: Any, *, mesh=None) -> Any:
    """MoE-aware FSDP variant: expert weights keep expert-parallel over
    "model" but put the FSDP ("data") leg on the *FFN* dimension instead of
    d_model. Contracting dims stay unsharded for wg/wu, so their (C, ff)
    outputs need NO all-reduce; only wo's (C, d) partial sum reduces —
    ~75% of the MoE-layer all-reduce bytes removed vs. the baseline, while
    per-chip expert memory stays 1/(16*16) of total."""
    full = param_specs(params, mesh=mesh)

    def fix(path, spec, leaf):
        s = _path_str(path)
        if re.search(r"moe/w[gu]$", s):
            return _div_guard(P(None, "model", None, "data"), leaf, mesh)
        if re.search(r"moe/wo$", s):
            return _div_guard(P(None, "model", "data", None), leaf, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(
        lambda p, sp, lf: fix(p, sp, lf), full, params)


def _div_guard(spec, leaf, mesh):
    if mesh is None:
        return spec
    parts = tuple(
        a if (a is None or leaf.shape[i] % _axis_size(mesh, a) == 0)
        else None for i, a in enumerate(tuple(spec)[: leaf.ndim]))
    return P(*parts)


def param_specs_zero1_moe(params: Any, *, mesh=None) -> Any:
    """Hybrid ZeRO-1 compute layout for MoE archs: dense/attention weights
    TP-only (gathered bf16 per step — cheap, they're small), expert weights
    STAY sharded (model x ff-over-data — they're the bulk; gathering them
    is what made plain ZeRO-1 regress on dbrx)."""
    tp = param_specs_tp(params, mesh=mesh)
    moe = param_specs_moe_ff(params, mesh=mesh)

    def pick(path, tp_spec, moe_spec):
        s = _path_str(path)
        return moe_spec if re.search(r"moe/w[guo]$", s) else tp_spec

    return jax.tree_util.tree_map_with_path(pick, tp, moe)


def param_specs_tp(params: Any, *, mesh=None) -> Any:
    """Tensor-parallel-only variant: the FSDP ("data") leg dropped.

    Used by the ZeRO-1 optimized train step (compute weights bf16,
    TP-sharded, data-replicated; master params + optimizer state stay
    fully sharded) and by TP-only serving. Removing the contracting-dim
    "data" sharding stops GSPMD from resolving matmuls as partial-sum +
    activation all-reduce (the dominant collective in the baseline)."""
    full = param_specs(params, mesh=mesh)

    def strip(spec):
        return P(*[None if a == "data" else a for a in spec])

    return jax.tree_util.tree_map(
        strip, full, is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh, axis):
    if isinstance(axis, tuple):
        r = 1
        for a in axis:
            r *= mesh.shape[a]
        return r
    return mesh.shape[axis]


def batch_specs(batch: Any, mesh) -> Any:
    """Shard the leading (global-batch) dim over pod+data axes.
    Batches smaller than the dp extent (e.g. long_500k's batch=1) stay
    replicated on that dim."""
    dp = _dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)

    def spec_for(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dp_size != 0:
            return P(*([None] * leaf.ndim))
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(spec_for, batch)


def cache_specs(cache: Any, mesh) -> Any:
    """KV caches: batch over data axes, sequence dim over "model".

    Leaf shapes: kv (B, L, Hkv, dh) -> P(dp, "model", None, None);
    ssm/xlstm states (B, ...) -> P(dp, None...); scalars replicated.
    """
    dp = _dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)
    msize = mesh.shape["model"]

    def spec_for(path, leaf):
        name = _path_str(path).rsplit("/", 1)[-1]
        nd = leaf.ndim
        if nd == 0:
            return P()
        # stacked caches have a leading n_units axis; dims: (units, B, ...)
        b_ok = nd >= 2 and leaf.shape[1] % dp_size == 0
        bspec = dp if b_ok else None
        if name in ("k", "v", "ck", "cv") and nd >= 5:
            s_ok = leaf.shape[2] % msize == 0
            return P(None, bspec, "model" if s_ok else None,
                     *([None] * (nd - 3)))
        if nd >= 2:
            return P(None, bspec, *([None] * (nd - 2)))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def client_flat_specs(sizes, mesh, axes=("data", "model"), align=1):
    """PartitionSpecs for the (1, C, n_l)-flattened per-client update
    leaves of the sharded robust-aggregation path
    (``aggregation.aggregate_sharded``): the flattened param axis shards
    over ``axes`` when its size divides the combined axis extent, else the
    leaf stays replicated (small norm/bias leaves — the fused pipeline
    de-duplicates them before its psum).  ``align`` additionally requires
    every SHARD to be a multiple of that many coordinates — the
    fused-dequant path passes its quant-block width so each device's code
    shard carries exactly its own scale columns.  Returns
    (specs, sharded_flags)."""
    axes = tuple(axes)
    size = _axis_size(mesh, axes)
    specs, flags = [], []
    for n in sizes:
        if n >= size and n % (size * align) == 0:
            specs.append(P(None, None, axes))
            flags.append(True)
        else:
            specs.append(P(None, None, None))
            flags.append(False)
    return tuple(specs), tuple(flags)


def client_store_specs(store, mesh, axes=("data", "model")) -> Any:
    """PartitionSpecs for the population-scale ClientStore
    (core/clientstore.py): every (M,) per-client column shards its
    population axis over the COMBINED ``axes`` extent when M divides it
    (a million-row registry spreads evenly over all devices; the per-row
    scalars have no other axis to shard), else the column replicates
    (the sync engine's M == K == tens-of-clients case).  Optional
    (M, ...)-leaved EF residual handles shard the same leading axis —
    the trailing param dims stay unsharded, since gather/scatter of the
    sampled cohort's rows is the only cross-shard traffic of the
    selection path and row-wise layout keeps it a single-axis
    all-gather."""
    axes = tuple(axes)
    size = _axis_size(mesh, axes)

    def spec_for(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % size != 0:
            return P(*([None] * leaf.ndim))
        return P(axes, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map(spec_for, store)


def client_flat_shardings(sizes, mesh, axes=("data", "model")):
    """``client_flat_specs`` as concrete ``NamedSharding``s — the layout
    the sharded robust-aggregation path constrains its *inputs* to
    (``jax.lax.with_sharding_constraint`` before the shard_map boundary),
    so the per-client backward emits grads already in the (C, shard)
    layout and the boundary does no reshard collective.  Returns
    (shardings, sharded_flags)."""
    specs, flags = client_flat_specs(sizes, mesh, axes)
    return tuple(NamedSharding(mesh, s) for s in specs), flags


def _dp_axes(mesh):
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
