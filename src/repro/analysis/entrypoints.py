"""The audited entry points.

Each :class:`EntryPoint` lazily builds a :class:`Target` — a concrete
jittable fn + args at linter scale (tiny models, small cohorts: the
invariants under audit are structural, not scale-dependent) — plus the
entry's declared expectations: copy-lint mode and threshold, collective
byte allowlist, donation expectations, rng-advance checks.

Registering a new entry point (see the package docstring for the full
guide)::

    @register_entry("my_entry", min_devices=1)
    def _build():
        fn, args = ...
        return Target(fn, args, copy_mode="engine",
                      copy_threshold=max_param_leaf, ...)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple


@dataclasses.dataclass
class Target:
    """One traced entry: the fn, its example args, and expectations."""
    fn: Callable
    args: Tuple
    donate_argnums: Tuple[int, ...] = ()
    copy_mode: str = "off"              # "strict" | "engine" | "off"
    copy_threshold: int = 0
    collective_allowlist: Optional[Dict[str, int]] = None
    donate_must_alias: Tuple = ()       # ((flat param number, path), ...)
    check_rng_advance: bool = False
    rules_off: Tuple[str, ...] = ()
    compile: bool = True                # lower+compile for hlo-kind rules
    hbm_pass_cap: Optional[float] = None   # fusion_count: max HBM-pass
                                           # multiple of the payload
    hbm_payload_bytes: int = 0             # one pass worth of bytes
    hbm_bytes_threshold: int = 0           # min buffer size that counts


@dataclasses.dataclass
class EntryPoint:
    name: str
    build: Callable[[], Target]
    min_devices: int = 1
    doc: str = ""


ENTRYPOINTS: Dict[str, EntryPoint] = {}


def register_entry(name: str, *, min_devices: int = 1, doc: str = ""):
    def deco(build_fn):
        ENTRYPOINTS[name] = EntryPoint(name, build_fn, min_devices, doc)
        return build_fn
    return deco


def _leaf_sizes(tree):
    import jax
    return [int(l.size) for l in jax.tree_util.tree_leaves(tree)
            if hasattr(l, "size")]


def _leaf_bytes(tree):
    import jax
    return sum(int(l.size) * int(l.dtype.itemsize)
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "size"))


def _must_alias(state, prefixes):
    """(flat param number, path) pairs for the heavy carry buffers that a
    donating entry MUST reuse in place.  Bookkeeping scalars that stay
    live past the return (e.g. fairness counters read by metrics) are
    legitimately copied, so the contract names buffer families by path
    prefix rather than demanding every leaf alias."""
    import jax
    flat, _ = jax.tree_util.tree_flatten_with_path(state)
    return tuple(
        (i, jax.tree_util.keystr(path))
        for i, (path, _leaf) in enumerate(flat)
        if any(jax.tree_util.keystr(path).startswith(p) for p in prefixes))


# --------------------------------------------------------------------- #
# aggregation kernels (strict copy lint: the PR-2 no-flatten contract)  #
# --------------------------------------------------------------------- #

def _mixed_tree(c, key=None):
    """The PR-2 guard's multi-leaf mixed-dtype odd-size tree."""
    import jax
    import jax.numpy as jnp
    key = key if key is not None else jax.random.PRNGKey(0)
    return {"a": jax.random.normal(key, (c, 13, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (c, 301)).astype(jnp.bfloat16),
            "c": jax.random.normal(jax.random.fold_in(key, 2), (c, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 3),
                                   (c, 192)).astype(jnp.float16)}


@register_entry("aggregate", doc="fused Eq.-11 tree aggregation")
def _build_aggregate():
    import jax.numpy as jnp

    from repro.configs.base import FedConfig
    from repro.core import aggregation

    c = 8
    tree = _mixed_tree(c)
    cfg = FedConfig(n_clients=c, aggregator="trimmed_mean")
    w = jnp.ones((c,))
    mask = jnp.ones((c,)).at[2].set(0.0)

    def fn(u, ww, m):
        return aggregation.aggregate(u, ww, m, cfg)

    # fusion_count: one pass = the (C, ...) cohort update tree.  The CPU
    # backend inlines the pallas rank-compare kernels, whose (C, C, leaf)
    # comparison tensors put the fused baseline at ~C passes/leaf
    # (measured 64.0x at this fixture scale) — the cap is a regression
    # tripwire ~10% above: an un-fused mean path or an extra
    # comparison-tensor materialization jumps it by ~C, far past the
    # headroom, while run-to-run XLA jitter stays inside it.
    return Target(fn, (tree, w, mask), copy_mode="strict",
                  copy_threshold=min(_leaf_sizes(tree)),
                  collective_allowlist={},
                  hbm_pass_cap=70.0, hbm_payload_bytes=_leaf_bytes(tree),
                  hbm_bytes_threshold=128)


@register_entry("two_stage", doc="cohort-batched two-stage aggregation")
def _build_two_stage():
    import jax
    import jax.numpy as jnp

    from repro.configs.base import FedConfig
    from repro.core import aggregation

    g, k = 3, 8
    key = jax.random.PRNGKey(0)
    upd = {"w": jax.random.normal(key, (g, k, 57)),
           "b": jax.random.normal(jax.random.fold_in(key, 3), (g, k, 5, 3))}
    sw = jnp.ones((g, k))
    sm = jnp.ones((g, k)).at[0, 3].set(0.0)
    cfg = FedConfig(aggregator="trimmed_mean")

    def fn(u, w, m):
        return aggregation.two_stage(u, w, m, cfg)

    # two vmapped rank-compare stages (K-wide then G-wide) put the fused
    # baseline at 15.3x payload on this backend; tripwire ~10% above.
    return Target(fn, (upd, sw, sm), copy_mode="strict",
                  copy_threshold=min(_leaf_sizes(upd)),
                  collective_allowlist={},
                  hbm_pass_cap=17.0, hbm_payload_bytes=_leaf_bytes(upd),
                  hbm_bytes_threshold=128)


@register_entry("aggregate_sharded", min_devices=2,
                doc="mesh-sharded Eq.-11 aggregation (PR-3 contract)")
def _build_aggregate_sharded():
    import numpy as np

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.configs.base import FedConfig
    from repro.core import aggregation

    c = 8
    key = jax.random.PRNGKey(0)
    tree = {"w": jax.random.normal(key, (c, 64, 8)),
            "r": jax.random.normal(jax.random.fold_in(key, 1), (c, 301)),
            "b": jax.random.normal(jax.random.fold_in(key, 2), (c, 5)),
            "h": jax.random.normal(jax.random.fold_in(key, 3),
                                   (c, 256)).astype(jnp.bfloat16)}
    cfg = FedConfig(n_clients=c, aggregator="trimmed_mean")
    mesh = Mesh(np.array(jax.devices()), ("data",))
    w = jnp.ones((c,))
    mask = jnp.ones((c,))

    def fn(u, ww, m):
        return aggregation.aggregate_sharded(u, ww, m, cfg, mesh,
                                             axes=("data",))

    # only the (C,) cosine partials + per-leaf scales (and Krum's (C,C)
    # Gram) may cross devices; the per-leaf payload bytes/chip stay far
    # below one leaf. all-gather covers re-replicating the aggregated
    # rows at the boundary exit; all-to-all would mean the shard_map
    # entry resharded the flat axis — forbidden outright.
    payload = sum(_leaf_sizes(tree)) * 4
    # partitioned module: per-chip shards cut the rank-compare tensors
    # 4-fold but the shard_map exit re-replicates the aggregated rows
    # (all-gather results are fresh buffers); measured 19.9x payload
    # under the forced-4-device CI pass, tripwire ~10% above.
    return Target(fn, (tree, w, mask), copy_mode="strict",
                  copy_threshold=min(_leaf_sizes(tree)),
                  collective_allowlist={"all-reduce": 16 * 1024,
                                        "all-gather": payload,
                                        "reduce-scatter": payload,
                                        "collective-permute": payload},
                  hbm_pass_cap=22.0, hbm_payload_bytes=_leaf_bytes(tree),
                  hbm_bytes_threshold=128)


# --------------------------------------------------------------------- #
# round engines (engine copy lint, rng discipline, donation)            #
# --------------------------------------------------------------------- #

@register_entry("fedfits.make_round",
                doc="synchronous FedFiTS round body (Algorithm 1+2)")
def _build_sync_round():
    import jax
    import jax.numpy as jnp

    from repro.configs.base import FedConfig
    from repro.configs.registry import ARCHS
    from repro.core import fedfits
    from repro.data.pipeline import build_federation
    from repro.models.model import build

    k = 6
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(0, kind="tabular", n=240, n_clients=k,
                              batch_size=8, n_classes=10)
    cfg = FedConfig(n_clients=k, algorithm="fedfits", local_epochs=1,
                    local_lr=0.05, avail_prob=0.7,
                    aggregator="trimmed_mean")
    r_init, r_run = jax.random.split(jax.random.PRNGKey(0))
    state = fedfits.init_state(model.init(r_init), k, cfg, r_run)
    batch = dict(fed.data_fn(1, jax.random.PRNGKey(1)))
    batch["avail"] = jnp.ones((k,), jnp.float32)
    round_fn = fedfits.make_round(model, cfg)
    return Target(round_fn, (state, batch), donate_argnums=(0,),
                  copy_mode="engine",
                  copy_threshold=max(_leaf_sizes(state.params)),
                  collective_allowlist={}, check_rng_advance=True,
                  donate_must_alias=_must_alias(
                      state, (".params", ".rng", ".clients.ef")))


@register_entry("async_engine.make_async_round",
                doc="buffered-async round body (PR-6 engine)")
def _build_async_round():
    import jax

    from repro.configs.base import FedConfig
    from repro.configs.registry import ARCHS
    from repro.core import async_engine
    from repro.data.pipeline import build_federation
    from repro.models.model import build

    m, c = 12, 4
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(0, kind="tabular", n=360, n_clients=m,
                              batch_size=8, n_classes=10)
    cfg = FedConfig(n_clients=c, population=m, algorithm="fedavg",
                    aggregator="trimmed_mean", async_max_retries=2,
                    staleness_decay=0.5)
    r_init, r_run = jax.random.split(jax.random.PRNGKey(0))
    state = async_engine.init_async_state(model.init(r_init), cfg, r_run)
    round_fn = async_engine.make_async_round(model, cfg, fed.data,
                                             batch_size=8)
    return Target(round_fn, (state, {}), donate_argnums=(0,),
                  copy_mode="engine",
                  copy_threshold=max(_leaf_sizes(state.params)),
                  collective_allowlist={}, check_rng_advance=True,
                  donate_must_alias=_must_alias(
                      state, (".params", ".rng", ".buf.upd")))


@register_entry("pod.make_train_step",
                doc="pod SPMD train step (robust per-client aggregation)")
def _build_pod_step():
    import jax

    from repro.configs.base import FedConfig, TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import pod
    from repro.data import synthetic
    from repro.models import transformer
    from repro.optim import optimizers

    CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab_size=128,
                                   head_dim=16)
    C, B, S = 4, 8, 32
    key = jax.random.PRNGKey(0)
    fed = FedConfig(n_clients=C, aggregator="trimmed_mean")
    tc = TrainConfig(global_batch=B, seq_len=S, total_steps=4,
                     warmup_steps=1)
    params = transformer.init_transformer(key, CFG)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, C, fed, key)
    toks = synthetic.make_lm_tokens(key, B, S + 1, CFG.vocab_size,
                                    n_latent=2)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    step = pod.make_train_step(CFG, fed, tc, robust="per_client")
    # the transformer forward legitimately concats/reshapes at single-
    # activation size (RoPE rotate-half, attention head merges), so the
    # pod threshold is whole-tree scale: only a flatten materialization
    # of the full parameter tree (the anti-pattern the robust
    # aggregation path was built to avoid) can trip it.
    return Target(step, (state, batch), donate_argnums=(0,),
                  copy_mode="engine",
                  copy_threshold=sum(_leaf_sizes(params)),
                  collective_allowlist={}, check_rng_advance=True,
                  donate_must_alias=_must_alias(
                      state, (".params", ".opt_state", ".rng")))


@register_entry("examples.async_healthcare.round",
                doc="walkthrough async round with the telemetry column "
                    "riding the donated carry")
def _build_example_round():
    import importlib.util
    from pathlib import Path

    # examples/ is not a package: load the walkthrough module from the
    # repo root so the linter audits the EXACT round body users run —
    # the telemetry counter column must not break carry donation.
    path = (Path(__file__).resolve().parents[3]
            / "examples" / "async_healthcare.py")
    spec = importlib.util.spec_from_file_location(
        "_example_async_healthcare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    round_fn, state = mod.make_telemetry_round()
    return Target(round_fn, (state, {}), donate_argnums=(0,),
                  copy_mode="engine",
                  copy_threshold=max(_leaf_sizes(state.params)),
                  collective_allowlist={}, check_rng_advance=True,
                  donate_must_alias=_must_alias(
                      state, (".params", ".rng", ".buf.upd")))


# --------------------------------------------------------------------- #
# comm codec round-trips (rng + dtype discipline on the wire boundary)  #
# --------------------------------------------------------------------- #

def _codec_entry(name):
    import jax

    from repro.comm import codecs as comm_codecs, error_feedback
    from repro.configs.base import FedConfig

    cfg = FedConfig(n_clients=4, compress=name)
    codec = comm_codecs.make_codec(cfg)
    tree = _mixed_tree(4)
    residual = error_feedback.init(tree)

    def fn(u, r, rng):
        return error_feedback.compress(
            codec, u, r, rng=rng if codec.stochastic else None)

    return Target(fn, (tree, residual, jax.random.PRNGKey(3)),
                  copy_mode="off", collective_allowlist={},
                  copy_threshold=max(_leaf_sizes(tree)))


for _name in ("int8", "int4", "signsgd", "topk", "randk"):
    register_entry(f"comm.codec.{_name}",
                   doc=f"{_name} wire round-trip through EF")(
        lambda _n=_name: _codec_entry(_n))


# --------------------------------------------------------------------- #
# serving                                                               #
# --------------------------------------------------------------------- #

@register_entry("serve.decode_step",
                doc="autoregressive decode+sample step (launch/serve.py)")
def _build_decode_step():
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.launch.serve import make_decode_step
    from repro.models.model import build

    cfg = get_config("tiny-lm").reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, P = 2, 16
    cache = model.init_cache(B, P + 8, dtype=jnp.float32)
    # prefill positions [0, P) so the decode step sees a warm cache
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    _, cache = jax.jit(model.prefill)(params, {"tokens": prompts}, cache)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = make_decode_step(model, temperature=1.0)
    return Target(step, (params, tok, cache, jnp.int32(P),
                         jax.random.PRNGKey(7)),
                  copy_mode="engine",
                  copy_threshold=max(_leaf_sizes(params)),
                  collective_allowlist={}, check_rng_advance=True)


@register_entry("serve.paged_decode_step",
                doc="continuous-batching paged decode step "
                    "(serve/engine.py: flash-decode kernel, donated "
                    "pools + slot carry)")
def _build_paged_decode_step():
    import jax
    import numpy as np

    from repro.configs.registry import get_config
    from repro.models.model import build
    from repro.serve import Request, ServeConfig, ServeEngine

    cfg = get_config("tiny-lm").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    scfg = ServeConfig(max_slots=4, page_size=8, max_len=32,
                       prompt_pad=8, temperature=1.0, attn="pallas")
    engine = ServeEngine(cfg, scfg, params, seed=1)
    # warm two slots through the real admit path so the audited step
    # sees live page tables
    cache, st = engine.fresh_state()
    rng = np.random.RandomState(0)
    for rid in range(2):
        prompt = jax.numpy.zeros((scfg.prompt_pad,), jax.numpy.int32) \
            .at[:4].set(jax.numpy.asarray(
                rng.randint(0, cfg.vocab_size, 4), jax.numpy.int32))
        cache, st, _ = engine._admit(
            params, cache, st, prompt, jax.numpy.int32(4),
            jax.numpy.int32(8), jax.numpy.int32(rid))
    decode = engine._make_decode()
    off = len(jax.tree_util.tree_leaves(params))
    pool_alias = tuple(
        (off + i, jax.tree_util.keystr(path))
        for i, (path, _l) in enumerate(
            jax.tree_util.tree_flatten_with_path(cache)[0])
        if any(f"'{k}'" in jax.tree_util.keystr(path)
               for k in ("kp", "vp")))
    return Target(decode, (params, cache, st),
                  donate_argnums=(1, 2),
                  copy_mode="engine",
                  copy_threshold=max(_leaf_sizes(params)),
                  collective_allowlist={}, check_rng_advance=True,
                  donate_must_alias=pool_alias)


def get_entry(name: str) -> EntryPoint:
    return ENTRYPOINTS[name]
