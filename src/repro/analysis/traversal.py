"""Shared recursive jaxpr traversal.

One walker for every invariant check that inspects jaxprs: it descends
into any eqn param that holds a sub-jaxpr (scan/while/cond bodies, pjit
calls, shard_map, pallas_call kernels, custom_jvp/vjp rules), so a rule
written against :func:`all_eqns` sees the whole program, not just the
top level.  Replaces the per-test copies that used to live in
tests/test_robust_pipeline.py and tests/test_sharded_agg.py.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import jax
from jax import core as jcore


def subjaxprs_of(value) -> Iterator:
    """Yield every (open) Jaxpr held by an eqn-param value: a Jaxpr, a
    ClosedJaxpr, or a list/tuple of either (e.g. cond branches)."""
    if isinstance(value, jcore.Jaxpr):
        yield value
    elif isinstance(value, jcore.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from subjaxprs_of(item)


def sub_jaxprs(eqn) -> Iterator:
    """Every sub-jaxpr reachable from one eqn's params."""
    for value in eqn.params.values():
        yield from subjaxprs_of(value)


def all_eqns(jaxpr) -> Iterator[Tuple]:
    """Yield ``(jaxpr, eqn)`` for every eqn in `jaxpr` and, recursively,
    in every sub-jaxpr of every eqn.  Accepts a Jaxpr or ClosedJaxpr."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield jaxpr, eqn
        for sub in sub_jaxprs(eqn):
            yield from all_eqns(sub)


def eqn_provenance(eqn) -> str:
    """Best-effort ``file:line (fn)`` source location of an eqn, from the
    jaxpr's recorded source_info; '?' when tracing stripped it."""
    try:
        from jax._src import source_info_util  # noqa: PLC0415
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is None:
            return "?"
        return (f"{frame.file_name.rsplit('/', 1)[-1]}:{frame.start_line} "
                f"({frame.function_name})")
    except Exception:
        return "?"


def leaf_sizes(tree) -> list:
    """Element counts of the array leaves of a pytree (the size scale
    against which 'leaf-sized materialization' findings are judged)."""
    return sorted(
        int(leaf.size) for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "size"))
