"""Finding / report types for the invariant linter.

A `Finding` is one rule violation pinned to an entry point, with eqn
provenance when the rule works at the jaxpr level.  A `Report` collects
per-entry results plus informational notes (e.g. per-kernel VMEM
estimates) and serializes to the JSON artifact the CI gate uploads.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

SEV_ERROR = "error"
SEV_NOTE = "note"


@dataclasses.dataclass
class Finding:
    rule: str                       # registry name of the firing rule
    entry: str                      # entry-point name
    message: str
    severity: str = SEV_ERROR
    provenance: str = "?"           # file:line (fn) of the offending eqn
    primitive: Optional[str] = None
    shape: Optional[str] = None

    def to_dict(self) -> dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    def __str__(self) -> str:
        loc = f" @ {self.provenance}" if self.provenance != "?" else ""
        return (f"[{self.severity}] {self.entry} :: {self.rule}: "
                f"{self.message}{loc}")


@dataclasses.dataclass
class EntryResult:
    entry: str
    status: str = "ok"              # ok | findings | skipped
    findings: List[Finding] = dataclasses.field(default_factory=list)
    notes: List[str] = dataclasses.field(default_factory=list)
    skipped_reason: Optional[str] = None

    def to_dict(self) -> dict:
        d = {"entry": self.entry, "status": self.status,
             "findings": [f.to_dict() for f in self.findings],
             "notes": self.notes}
        if self.skipped_reason:
            d["skipped_reason"] = self.skipped_reason
        return d


@dataclasses.dataclass
class Report:
    results: List[EntryResult] = dataclasses.field(default_factory=list)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def add(self, result: EntryResult) -> None:
        self.results.append(result)

    @property
    def findings(self) -> List[Finding]:
        return [f for r in self.results for f in r.findings]

    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_ERROR]

    def to_dict(self) -> dict:
        return {
            "meta": self.meta,
            "summary": {
                "entries": len(self.results),
                "skipped": sum(r.status == "skipped" for r in self.results),
                "errors": len(self.errors()),
                "notes": (sum(len(r.notes) for r in self.results)
                          + sum(f.severity == SEV_NOTE
                                for f in self.findings)),
            },
            "results": [r.to_dict() for r in self.results],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)
