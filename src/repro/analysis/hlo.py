"""Shared compiled-HLO text helpers: shape/byte parsing, collective
extraction, and input/output alias maps.

One canonical parser for everything that reads ``compiled.as_text()``:
the roofline derivation (launch/roofline.py), the sharded-aggregation
collective guards (tests/test_sharded_agg.py), and the analysis rules
(rules.collective_lint / rules.donation_audit).  Collective bytes come
from the *partitioned* module, so they are per-chip; '-done' halves of
async pairs are skipped to avoid double counting.
"""
from __future__ import annotations

import re
from typing import Dict, Iterator, List, NamedTuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

SHAPE_RE = re.compile(r"(pred|[fsu]\d+|bf16|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COLL_RE = re.compile(r"=\s*(.*?)\s(" + "|".join(COLLECTIVES) + r")(-start)?\(")
_ALIAS_RE = re.compile(
    r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}")
_ALIAS_ENTRY_RE = re.compile(
    r"\{([0-9, ]*)\}:\s*\((\d+),\s*\{([0-9, ]*)\},?\s*([a-z-]*)\)")


def shape_bytes(segment: str) -> int:
    """Total bytes of every typed shape literal in an HLO text segment."""
    total = 0
    for dt, dims in SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


class CollectiveOp(NamedTuple):
    """One collective instruction: kind, operand bytes, source line."""
    kind: str
    bytes: int
    line: str


def iter_collectives(hlo_text: str) -> Iterator[CollectiveOp]:
    """Every collective instruction of a partitioned HLO module, with its
    per-chip operand bytes (the shape segment left of the op name)."""
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        yield CollectiveOp(m.group(2), shape_bytes(m.group(1)),
                           line.strip())


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Per-chip bytes by collective kind from partitioned HLO text."""
    out = {k: 0 for k in COLLECTIVES}
    for op in iter_collectives(hlo_text):
        out[op.kind] += op.bytes
    return out


class Materialization(NamedTuple):
    """One ENTRY-computation kernel writing a fresh HBM buffer."""
    op: str
    bytes: int
    line: str


# ops whose "output" is a view/plumbing, not a fresh HBM buffer
HBM_EXEMPT = frozenset({"parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast"})

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")


def iter_materializations(hlo_text: str,
                          min_bytes: int = 1) -> Iterator[Materialization]:
    """Top-level instructions of the ENTRY computation that write a new
    ``>= min_bytes`` buffer.  After fusion, every ENTRY-level instruction
    is one kernel launch whose output round-trips through HBM — summing
    their output bytes counts the HBM passes a program makes over its
    working set.  The exempt set (parameters, constants, tuple plumbing,
    bitcasts) produces views, not buffers; sub-computation bodies (fused
    or called) never materialize at module scope and are skipped."""
    in_entry = False
    for line in hlo_text.splitlines():
        s = line.strip()
        if not in_entry:
            if s.startswith("ENTRY "):
                in_entry = True
            continue
        if s.startswith("}"):
            break
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(2)
        if op in HBM_EXEMPT:
            continue
        b = shape_bytes(m.group(1))
        if b >= min_bytes:
            yield Materialization(op, b, s)


class AliasEntry(NamedTuple):
    """One input_output_alias map entry of a compiled module."""
    output_index: tuple
    param_number: int
    param_index: tuple
    kind: str                 # "may-alias" | "must-alias"


def parse_input_output_aliases(hlo_text: str) -> List[AliasEntry]:
    """The ``input_output_alias={ {out}: (param, {idx}, kind), ... }``
    header of a compiled HLO module — the ground truth of whether buffer
    donation actually took effect (a donated-but-unaliased parameter is
    silently copied instead of reused)."""
    m = _ALIAS_RE.search(hlo_text)
    if not m:
        return []
    out = []
    for om, pnum, pidx, kind in _ALIAS_ENTRY_RE.findall(m.group(1)):
        to_tuple = lambda s: tuple(
            int(x) for x in s.replace(" ", "").split(",") if x)
        out.append(AliasEntry(to_tuple(om), int(pnum), to_tuple(pidx),
                              kind or "may-alias"))
    return out


def aliased_param_numbers(hlo_text: str) -> set:
    """Flat parameter numbers that alias some output buffer."""
    return {e.param_number for e in parse_input_output_aliases(hlo_text)}
