"""Entry-point audit CLI.

  PYTHONPATH=src python -m repro.analysis.lint --all [--json report.json]
  PYTHONPATH=src python -m repro.analysis.lint --entry aggregate --entry two_stage
  PYTHONPATH=src python -m repro.analysis.lint --list

Traces every registered entry point (repro/analysis/entrypoints.py) to
its jaxpr and compiled HLO, runs the rule registry
(repro/analysis/rules.py) over them, prints findings, and exits nonzero
when any finding at/above --fail-on severity survives.  Entries needing
more devices than available (e.g. aggregate_sharded) are SKIPPED with a
note — the CI matrix runs both a plain-CPU and a forced-4-device pass so
the collective rules always bite somewhere.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.analysis import entrypoints as ep
from repro.analysis import rules as rules_mod
from repro.analysis.report import SEV_NOTE, EntryResult, Report


def audit_entry(entry: ep.EntryPoint) -> EntryResult:
    """Trace + (best-effort) compile one entry and run every rule."""
    result = EntryResult(entry=entry.name)
    if jax.device_count() < entry.min_devices:
        result.status = "skipped"
        result.skipped_reason = (
            f"needs >= {entry.min_devices} devices, have "
            f"{jax.device_count()} (run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
        return result
    target = entry.build()
    jaxpr = jax.make_jaxpr(target.fn)(*target.args)
    hlo_text = None
    if target.compile:
        try:
            hlo_text = (jax.jit(target.fn,
                                donate_argnums=target.donate_argnums)
                        .lower(*target.args).compile().as_text())
        except Exception as e:              # pragma: no cover - backend gaps
            result.notes.append(f"compile unavailable: {type(e).__name__}: "
                                f"{e}; hlo rules skipped")
    ctx = rules_mod.RuleContext(
        entry_name=entry.name, jaxpr=jaxpr, result=result,
        hlo_text=hlo_text, copy_mode=target.copy_mode,
        copy_threshold=target.copy_threshold,
        collective_allowlist=target.collective_allowlist,
        donate_must_alias=target.donate_must_alias,
        check_rng_advance=target.check_rng_advance,
        rules_off=target.rules_off,
        hbm_pass_cap=target.hbm_pass_cap,
        hbm_payload_bytes=target.hbm_payload_bytes,
        hbm_bytes_threshold=target.hbm_bytes_threshold)
    return rules_mod.run_rules(ctx)


def run(names=None) -> Report:
    report = Report(meta={
        "devices": jax.device_count(),
        "backend": jax.default_backend(),
        "rules": sorted(rules_mod.RULES),
    })
    for name, entry in ep.ENTRYPOINTS.items():
        if names and name not in names:
            continue
        report.add(audit_entry(entry))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jaxpr/HLO invariant linter over the registered "
                    "entry points")
    ap.add_argument("--all", action="store_true",
                    help="audit every registered entry point")
    ap.add_argument("--entry", action="append", default=[],
                    help="audit one entry (repeatable); see --list")
    ap.add_argument("--list", action="store_true",
                    help="list registered entry points and exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the JSON report here")
    ap.add_argument("--fail-on", choices=["error", "note"],
                    default="error",
                    help="exit nonzero on findings at/above this severity")
    args = ap.parse_args(argv)

    if args.list:
        for name, entry in ep.ENTRYPOINTS.items():
            gate = (f" [>= {entry.min_devices} devices]"
                    if entry.min_devices > 1 else "")
            print(f"{name:32s} {entry.doc}{gate}")
        return 0
    if not args.all and not args.entry:
        ap.error("pick --all, --entry NAME, or --list")
    unknown = [n for n in args.entry if n not in ep.ENTRYPOINTS]
    if unknown:
        ap.error(f"unknown entries {unknown}; see --list")

    report = run(set(args.entry) or None)
    if args.json:
        with open(args.json, "w") as f:
            f.write(report.to_json())

    for res in report.results:
        if res.status == "skipped":
            print(f"SKIP {res.entry}: {res.skipped_reason}")
            continue
        mark = "FAIL" if res.findings else "ok  "
        print(f"{mark} {res.entry}")
        for note in res.notes:
            print(f"       note: {note}")
        for f in res.findings:
            print(f"       {f}")

    failing = report.errors() if args.fail_on == "error" \
        else report.findings
    n_err = len(failing)
    n_skip = sum(r.status == "skipped" for r in report.results)
    print(f"\n{len(report.results)} entries audited "
          f"({n_skip} skipped), {n_err} finding(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
