"""Static analysis of the repo's jittable entry points — the invariant
linter behind ``python -m repro.analysis.lint``.

The performance and trustworthiness story of this codebase rests on
structural invariants (no flatten materialization on the aggregation
path, disciplined PRNG streams, live buffer donation, fp32 accumulation,
bounded collectives, VMEM-sized kernels).  This package makes them
checked facts on EVERY entry point instead of folklore enforced by
copy-pasted jaxpr walkers in two tests:

  traversal.py    shared recursive jaxpr walker (scan/cond/shard_map/
                  pallas_call sub-jaxprs) + eqn provenance
  hlo.py          shared compiled-HLO text parsing (collective bytes,
                  input_output_alias maps) — launch/roofline.py routes
                  through the same parser
  report.py       Finding / EntryResult / Report (the CI JSON artifact)
  rules.py        the rule registry (copy lint, rng discipline, donation
                  audit, dtype discipline, Pallas VMEM budget,
                  collective allowlists)
  entrypoints.py  the audited entry points, built lazily at linter scale
  lint.py         the CLI: ``--all | --entry NAME | --list``, JSON
                  report, nonzero exit on findings (the CI gate)

Rule-author guide
-----------------

**Registering an entry point** (entrypoints.py): decorate a zero-arg
builder returning a :class:`~repro.analysis.entrypoints.Target`::

    @register_entry("my_engine.make_step", min_devices=1,
                    doc="one-line description for --list")
    def _build():
        fn, args = ...            # a jittable fn + SMALL example args
        return Target(fn, args,
                      donate_argnums=(0,),        # audit donation
                      donate_must_alias=_must_alias(   # heavy carries that
                          state, (".params", ".rng")),  # must reuse buffers
                      copy_mode="engine",         # or "strict" / "off"
                      copy_threshold=max_leaf,    # eqn size that counts
                      collective_allowlist={},    # {} = none allowed
                      check_rng_advance=True)     # carry rng must move

Keep builders lazy (imports inside) and tiny — the invariants are
structural, so linter-scale models keep ``--all`` cheap.  Entries whose
invariants only bite on a mesh set ``min_devices``; the CLI skips them
with a note on smaller hosts and the CI forced-4-device pass covers
them.

**Writing a rule** (rules.py): decorate a function over a
:class:`~repro.analysis.rules.RuleContext`::

    @register_rule("my_rule", kind="jaxpr")        # or kind="hlo"
    def my_rule(ctx):
        for jaxpr, eqn in traversal.all_eqns(ctx.jaxpr):
            if bad(eqn):
                ctx.finding("my_rule", "what broke and why it matters",
                            eqn)                   # provenance attached

``kind="hlo"`` rules read ``ctx.hlo_text`` (compiled module text; use
``repro.analysis.hlo`` helpers) and are skipped when compilation is
unavailable.  Emit ``ctx.note(...)`` for non-gating diagnostics (e.g.
per-kernel VMEM estimates).  Per-entry opt-outs go through
``Target.rules_off`` — prefer tightening the rule over opting out.

**Setting a collective allowlist**: ``collective_allowlist`` maps
collective kind -> max total per-chip operand bytes; kinds absent from
the dict are forbidden outright, ``{}`` forbids all collectives, and
``None`` disables the rule for that entry.  Derive caps from what the
entry legitimately moves (e.g. (C,) partials + the (C, C) Gram for
``aggregate_sharded``) with modest headroom — a param-sized operand
crossing the interconnect should always trip the cap.

Every rule must demonstrate BOTH directions in tests/test_analysis.py:
silent on the clean entry points, firing on a deliberately violating
twin program.
"""
from repro.analysis import hlo, report, traversal  # noqa: F401
from repro.analysis.report import Finding, Report  # noqa: F401
from repro.analysis.traversal import all_eqns, subjaxprs_of  # noqa: F401
