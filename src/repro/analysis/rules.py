"""The invariant rule registry.

Each rule is a function over a :class:`RuleContext` (one traced entry
point: its jaxpr, optionally its compiled HLO text, and the entry's
declared expectations) that appends :class:`~repro.analysis.report.Finding`s.
Register with ``@register_rule(name, kind=...)``; ``kind='jaxpr'`` rules
always run, ``kind='hlo'`` rules run only when the entry was compiled
(some need a multi-device mesh and are skipped otherwise).

Rules shipped here:

``copy_lint``        no leaf-sized concatenate (flatten materialization)
                     on the aggregation path; in ``engine`` mode also no
                     leaf-sized transpose-fed reshape (a copy in disguise),
                     while the async buffer's axis-0 row concatenation
                     stays legal.
``rng_discipline``   every sampled key derives from a distinct
                     fold_in/split; no key class is consumed twice
                     (the scan==python bit-parity story depends on this).
``donation_audit``   a donated carry must actually alias in the compiled
                     executable's input_output_alias map, and a PRNG-key
                     carry leaf must come back advanced (not the same var).
``dtype_discipline`` accumulation stays fp32: no leaf-sized reduce/add/
                     contraction producing half precision, and at most one
                     leaf-sized fp32->half cast per half-precision output.
``pallas_budget``    per-pallas_call VMEM working-set estimate from the
                     grid_mapping's BlockSpecs; over-budget is an error,
                     lane-minor (minor dim < 128) block layouts are
                     reported as notes feeding the "(C,) lane-minor"
                     follow-up.
``fusion_count``     HBM-pass budget over the compiled ENTRY computation:
                     total bytes its kernels materialize, in multiples of
                     the cohort update payload, stays under the entry's
                     cap (a flatten/copy/re-sort chain doubles traffic).
``collective_lint``  per-entry byte allowlists over the compiled module's
                     collectives (e.g. aggregate_sharded may psum small
                     partials but never all-to-all).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.analysis import hlo as hlo_mod
from repro.analysis import traversal as tv
from repro.analysis.report import (SEV_ERROR, SEV_NOTE, EntryResult, Finding)

# VMEM is ~16 MiB/core; leave headroom for pipelining/semaphores.
VMEM_BYTES = 16 * 1024 * 1024
VMEM_BUDGET = int(0.75 * VMEM_BYTES)
LANE = 128


@dataclasses.dataclass
class Rule:
    name: str
    kind: str                       # "jaxpr" | "hlo"
    fn: Callable


RULES: Dict[str, Rule] = {}


def register_rule(name: str, kind: str = "jaxpr"):
    """Register an invariant rule. The decorated fn takes a RuleContext
    and appends findings/notes to it."""
    def deco(fn):
        RULES[name] = Rule(name, kind, fn)
        return fn
    return deco


@dataclasses.dataclass
class RuleContext:
    """One entry point under analysis, as seen by the rules."""
    entry_name: str
    jaxpr: object                           # ClosedJaxpr of the traced fn
    result: EntryResult
    hlo_text: Optional[str] = None          # compiled module text, if any
    # entry expectations (set by the entry-point registry):
    copy_mode: str = "off"                  # "strict" | "engine" | "off"
    copy_threshold: int = 0                 # eqn output size that counts
    collective_allowlist: Optional[Dict[str, int]] = None
    donate_must_alias: tuple = ()           # flat param numbers that must
                                            # alias (with their path labels)
    check_rng_advance: bool = False
    rules_off: tuple = ()                   # rule names disabled per entry
    hbm_pass_cap: Optional[float] = None    # max HBM-pass multiple of the
                                            # payload (fusion_count)
    hbm_payload_bytes: int = 0              # one pass worth of bytes
    hbm_bytes_threshold: int = 0            # min buffer size that counts

    def finding(self, rule, message, eqn=None, severity=SEV_ERROR):
        f = Finding(
            rule=rule, entry=self.entry_name, message=message,
            severity=severity,
            provenance=tv.eqn_provenance(eqn) if eqn is not None else "?",
            primitive=eqn.primitive.name if eqn is not None else None,
            shape=(str(eqn.outvars[0].aval)
                   if eqn is not None and eqn.outvars else None))
        self.result.findings.append(f)

    def note(self, message):
        self.result.notes.append(message)


def _out_size(eqn) -> int:
    aval = eqn.outvars[0].aval
    return int(np.prod(getattr(aval, "shape", ()) or (1,)))


# --------------------------------------------------------------------- #
# 1. copy lint                                                          #
# --------------------------------------------------------------------- #

@register_rule("copy_lint")
def copy_lint(ctx: RuleContext) -> None:
    """No leaf-sized flatten materialization on the aggregation path.

    strict (kernels): ANY concatenate with output >= threshold fires —
    the leaf-streaming engines must never rebuild a (C, N) flat matrix.
    engine (round engines): only minor-axis concatenates fire (a flatten
    glues leaves along the last axis); the async delivery buffer's
    leading-axis row concatenation of (rows, ...) stacks is legitimate.
    Both modes flag leaf-sized reshapes fed by a transpose — XLA must
    materialise the permuted operand to relayout it.
    """
    if ctx.copy_mode == "off":
        return
    producers = {}
    for j, eqn in tv.all_eqns(ctx.jaxpr):
        for ov in eqn.outvars:
            producers[id(ov)] = eqn
    for j, eqn in tv.all_eqns(ctx.jaxpr):
        name = eqn.primitive.name
        if name == "concatenate" and _out_size(eqn) >= ctx.copy_threshold:
            ndim = len(eqn.outvars[0].aval.shape)
            dim = eqn.params.get("dimension", 0)
            if ctx.copy_mode == "strict" or dim == ndim - 1:
                ctx.finding(
                    "copy_lint",
                    f"leaf-sized concatenate (axis {dim} of {ndim}d, "
                    f"{_out_size(eqn)} elems >= {ctx.copy_threshold}): "
                    "flatten materialization on the aggregation path",
                    eqn)
        elif name == "reshape" and _out_size(eqn) >= ctx.copy_threshold:
            src = producers.get(id(eqn.invars[0]))
            if src is not None and src.primitive.name == "transpose":
                ctx.finding(
                    "copy_lint",
                    f"leaf-sized reshape of a transposed operand "
                    f"({_out_size(eqn)} elems): forces a relayout copy",
                    eqn)


# --------------------------------------------------------------------- #
# 2. RNG discipline                                                     #
# --------------------------------------------------------------------- #

# ops that alias a key value (same bits, new var)
_KEY_ALIAS = {"random_wrap", "random_unwrap"}
# ops that derive fresh, independent key material (not a consumption)
_KEY_DERIVE = {"random_split", "random_fold_in", "random_seed",
               "random_clone"}
# ops that spend a key's entropy: sampling from the same class twice
# yields correlated streams
_KEY_CONSUME = {"random_bits"}
_CALL_LIKE = {"pjit", "closed_call", "core_call", "remat", "checkpoint",
              "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
              "scan", "while", "cond", "shard_map"}


def _is_key_var(v) -> bool:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "dtype"):
        return False
    try:
        import jax
        return jax.dtypes.issubdtype(aval.dtype, jax.dtypes.prng_key)
    except Exception:
        return False


def _inner_jaxpr_invars(eqn):
    """Map each sub-jaxpr of a call-like eqn to the slice of eqn.invars
    feeding its invars positionally (best effort across primitives)."""
    out = []
    name = eqn.primitive.name
    subs = list(tv.sub_jaxprs(eqn))
    if name == "cond":
        # invars[0] is the predicate/index; branches share invars[1:]
        for sub in subs:
            out.append((sub, list(eqn.invars[1:])))
        return out
    if name == "while":
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        cond_j, body_j = subs[0], subs[1]
        carry = list(eqn.invars[cn + bn:])
        out.append((cond_j, list(eqn.invars[:cn]) + carry))
        out.append((body_j, list(eqn.invars[cn:cn + bn]) + carry))
        return out
    # scan, pjit, shard_map, remat, custom_*: invars align positionally
    # (scan: consts + carry + xs == body invars, xs lose the lead axis)
    for sub in subs:
        out.append((sub, list(eqn.invars)))
    return out


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[ra] = rb


def _consumption_events(jaxpr, memo) -> Dict[int, List[object]]:
    """Per-invar-position consumption events of one (open) jaxpr:
    position -> list of consuming eqns, counting nested call-like eqns
    by their inner jaxprs' consumption of the matching position.
    Also records intra-jaxpr reuse findings into memo['_reuse']."""
    key = id(jaxpr)
    if key in memo:
        return memo[key]
    memo[key] = {}                          # cycle guard

    uf = _UnionFind()
    for j, eqn in [(jaxpr, e) for e in jaxpr.eqns]:
        if eqn.primitive.name in _KEY_ALIAS:
            uf.union(id(eqn.invars[0]), id(eqn.outvars[0]))

    # class -> list of consumer eqns (one entry per consumption event)
    events: Dict[int, List[object]] = {}

    def consume(var, eqn, times=1):
        root = uf.find(id(var))
        events.setdefault(root, []).extend([eqn] * times)

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _KEY_CONSUME:
            consume(eqn.invars[0], eqn)
        elif name in _CALL_LIKE:
            for sub, outer_vars in _inner_jaxpr_invars(eqn):
                inner = _consumption_events(sub, memo)
                for pos, consumers in inner.items():
                    if pos < len(outer_vars) and consumers:
                        v = outer_vars[pos]
                        if hasattr(v, "aval"):   # skip Literals
                            consume(v, eqn, times=len(consumers))
        # _KEY_DERIVE and everything else: no consumption; their outputs
        # are fresh classes (slice/squeeze of split outputs likewise)

    reuse = memo.setdefault("_reuse", [])
    invar_roots = {uf.find(id(v)): i for i, v in enumerate(jaxpr.invars)}
    per_pos: Dict[int, List[object]] = {}
    for root, consumers in events.items():
        if len(consumers) >= 2:
            reuse.append((jaxpr, consumers))
        if root in invar_roots:
            per_pos[invar_roots[root]] = consumers
    memo[key] = per_pos
    return per_pos


@register_rule("rng_discipline")
def rng_discipline(ctx: RuleContext) -> None:
    """No PRNG key class may be consumed twice. Keys consumed by sampling
    (``random_bits``) or handed to a call-like eqn whose body samples them
    count as spent; ``split``/``fold_in`` derive fresh classes and do not.
    Two sampling eqns fed from one wrap/unwrap alias class means two
    correlated streams — exactly the bug class that breaks the
    scan==python bit-parity contract."""
    if "rng_discipline" in ctx.rules_off:
        return
    memo: dict = {}
    _consumption_events(ctx.jaxpr.jaxpr, memo)
    seen = set()
    for jaxpr, consumers in memo.get("_reuse", []):
        sig = (id(jaxpr), tuple(sorted(id(e) for e in set(consumers))))
        if sig in seen:
            continue
        seen.add(sig)
        prims = sorted({e.primitive.name for e in consumers})
        ctx.finding(
            "rng_discipline",
            f"PRNG key consumed {len(consumers)}x by {prims}: every "
            "sampling site must use a distinct fold_in/split derivation",
            consumers[-1])


# --------------------------------------------------------------------- #
# 3. donation audit                                                     #
# --------------------------------------------------------------------- #

@register_rule("donation_audit", kind="hlo")
def donation_audit(ctx: RuleContext) -> None:
    """Donated carries must actually alias. ``donate_argnums`` is a
    request — XLA silently drops it (and copies) when shapes/dtypes drift
    between carry-in and carry-out or the input stays live past the
    output write, so the executable's ``input_output_alias`` map is the
    only ground truth.  The entry declares WHICH flat params must alias
    (the heavy carry buffers: params, opt state, EF residuals, delivery
    buffer rows, the rng) — tiny bookkeeping scalars XLA chooses to copy
    are not the contract."""
    if not ctx.donate_must_alias or ctx.hlo_text is None:
        return
    aliased = hlo_mod.aliased_param_numbers(ctx.hlo_text)
    missing = [(i, label) for i, label in ctx.donate_must_alias
               if i not in aliased]
    if missing:
        ctx.finding(
            "donation_audit",
            f"donated carry buffers NOT aliased in the compiled module: "
            f"{missing} (param number, carry path) — the donation was "
            "dropped and these buffers are copied every round", None)


@register_rule("rng_advance")
def rng_advance(ctx: RuleContext) -> None:
    """A PRNG carry leaf must come back advanced: if a key-typed (or raw
    ``u32[2]`` PRNGKey) input var is returned as an output var unchanged,
    the next round replays the same bits — the PR-3 donation footgun's
    jaxpr-visible half."""
    if not ctx.check_rng_advance:
        return
    jaxpr = ctx.jaxpr.jaxpr
    out_ids = {id(v) for v in jaxpr.outvars}
    for v in jaxpr.invars:
        aval = getattr(v, "aval", None)
        if aval is None:
            continue
        is_raw_key = (getattr(aval, "shape", None) == (2,)
                      and str(getattr(aval, "dtype", "")) == "uint32")
        if (_is_key_var(v) or is_raw_key) and id(v) in out_ids:
            ctx.finding(
                "rng_advance",
                "PRNG carry leaf returned unadvanced (output var == input "
                "var): the next round replays identical random bits", None)


# --------------------------------------------------------------------- #
# 4. dtype discipline                                                   #
# --------------------------------------------------------------------- #

# reduction/contraction prims whose output dtype IS the accumulator
# dtype; a lone elementwise `add` (EF inject, params+update) is not an
# accumulation chain and stays legal in the leaf dtype
_ACCUM_PRIMS = {"reduce_sum", "dot_general", "cumsum",
                "reduce_window_sum"}
_HALF = {"bfloat16", "float16"}


@register_rule("dtype_discipline")
def dtype_discipline(ctx: RuleContext) -> None:
    """Accumulation chains stay fp32, one cast per leaf at the write.
    (a) any leaf-sized add/reduce_sum/dot_general producing a half dtype
    is a half-precision accumulation; (b) more leaf-sized fp32->half
    casts than half-precision outputs means per-slice round-trip casts
    inside the chain (the drift the fused kernels were built to avoid)."""
    if "dtype_discipline" in ctx.rules_off:
        return
    threshold = max(ctx.copy_threshold, 1)
    half_casts = []
    for j, eqn in tv.all_eqns(ctx.jaxpr):
        name = eqn.primitive.name
        if not eqn.outvars:
            continue
        aval = eqn.outvars[0].aval
        dt = str(getattr(aval, "dtype", ""))
        if _out_size(eqn) < threshold:
            continue
        if name in _ACCUM_PRIMS and dt in _HALF:
            ctx.finding(
                "dtype_discipline",
                f"half-precision accumulation: {name} -> {dt} at "
                f"{_out_size(eqn)} elems (accumulate fp32, cast at the "
                "write)", eqn)
        elif (name == "convert_element_type" and dt in _HALF
              and str(getattr(eqn.invars[0].aval, "dtype", ""))
              == "float32"):
            half_casts.append(eqn)
    n_half_out = sum(
        1 for a in ctx.jaxpr.out_avals
        if str(getattr(a, "dtype", "")) in _HALF)
    if len(half_casts) > max(n_half_out, 0) and half_casts:
        ctx.finding(
            "dtype_discipline",
            f"{len(half_casts)} leaf-sized fp32->half casts for "
            f"{n_half_out} half-precision outputs: more than one cast "
            "per leaf means mid-chain precision round-trips",
            half_casts[-1])


# --------------------------------------------------------------------- #
# 5. Pallas budget                                                      #
# --------------------------------------------------------------------- #

def _block_bytes(bm) -> int:
    shape = tuple(d if isinstance(d, int) else 1
                  for d in getattr(bm, "block_shape", ()) or ())
    aval = getattr(bm, "block_aval", None)
    itemsize = 4
    for attr in ("dtype", "inner_aval"):
        obj = getattr(aval, attr, None)
        if obj is None:
            continue
        dt = getattr(obj, "dtype", obj)
        itemsize = getattr(dt, "itemsize", 4)
        break
    return int(np.prod(shape or (1,))) * int(itemsize)


@register_rule("pallas_budget")
def pallas_budget(ctx: RuleContext) -> None:
    """Per-pallas_call VMEM working-set estimate: 2x (double buffering)
    the summed block bytes of all in/out BlockSpecs. Over ~75% of the
    16 MiB VMEM is an error; lane-minor block layouts (minor dim < 128
    and != 1) are emitted as notes — data for the "(C,) lane-minor"
    carry-over, not a gate."""
    if "pallas_budget" in ctx.rules_off:
        return
    for j, eqn in tv.all_eqns(ctx.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            continue
        bms = list(getattr(gm, "block_mappings", ()) or ())
        working = 2 * sum(_block_bytes(bm) for bm in bms)
        name = str(eqn.params.get("name_and_src_info", "pallas_call"))
        name = name.split(" ")[0]
        grid = tuple(getattr(gm, "grid", ()) or ())
        lane_minor = []
        for bm in bms:
            shape = tuple(d if isinstance(d, int) else 1
                          for d in getattr(bm, "block_shape", ()) or ())
            if shape and 1 < shape[-1] < LANE:
                lane_minor.append(shape)
        ctx.note(
            f"pallas kernel {name}: grid={grid} blocks={len(bms)} "
            f"vmem~{working / 1024:.0f}KiB"
            + (f" lane-minor blocks={lane_minor}" if lane_minor else ""))
        if working > VMEM_BUDGET:
            ctx.finding(
                "pallas_budget",
                f"kernel {name} VMEM working set ~{working >> 20}MiB "
                f"(2x block bytes) exceeds the {VMEM_BUDGET >> 20}MiB "
                "budget: shrink the BlockSpecs or add a grid dimension",
                eqn)


# --------------------------------------------------------------------- #
# 6. fusion count                                                       #
# --------------------------------------------------------------------- #

@register_rule("fusion_count", kind="hlo")
def fusion_count(ctx: RuleContext) -> None:
    """The aggregation path stays fused: total bytes the ENTRY
    computation's kernels write to HBM, measured in multiples of the
    cohort update payload ("HBM passes"), must stay under the entry's
    cap.  A fused trimmed-mean makes ~1 pass (the per-leaf cohort-axis
    sort) plus the 1/C-sized aggregated outputs; a flatten+copy chain,
    a re-sorted intermediate, or a dropped fusion roughly doubles the
    traffic, so the cap catches the regression class PR 2's streaming
    kernels were built to eliminate."""
    if (ctx.hbm_pass_cap is None or not ctx.hbm_payload_bytes
            or ctx.hlo_text is None):
        return
    floor = max(ctx.hbm_bytes_threshold, 1)
    mats = list(hlo_mod.iter_materializations(ctx.hlo_text,
                                              min_bytes=floor))
    total = sum(m.bytes for m in mats)
    passes = total / ctx.hbm_payload_bytes
    ctx.note(f"hbm passes: {passes:.2f}x payload ({total}B over "
             f"{len(mats)} kernels >= {floor}B, cap {ctx.hbm_pass_cap}x)")
    if passes > ctx.hbm_pass_cap:
        top = sorted(mats, key=lambda m: -m.bytes)[:3]
        ctx.finding(
            "fusion_count",
            f"aggregation path materializes {passes:.2f}x the cohort "
            f"payload in HBM ({total}B vs {ctx.hbm_payload_bytes}B "
            f"payload across {len(mats)} kernels), cap "
            f"{ctx.hbm_pass_cap}x: XLA is spilling intermediates — "
            "largest: " + "; ".join(f"{m.op}:{m.bytes}B" for m in top),
            None)


# --------------------------------------------------------------------- #
# 7. collective lint                                                    #
# --------------------------------------------------------------------- #

@register_rule("collective_lint", kind="hlo")
def collective_lint(ctx: RuleContext) -> None:
    """Per-entry collective allowlist over the compiled module: each
    collective kind's total per-chip operand bytes must stay under the
    entry's declared cap; kinds absent from the allowlist are forbidden
    outright (aggregate_sharded may psum (C,) partials + the Gram matrix
    but must never all-to-all or all-gather a param-sized operand)."""
    if ctx.collective_allowlist is None or ctx.hlo_text is None:
        return
    totals: Dict[str, int] = {}
    sample: Dict[str, hlo_mod.CollectiveOp] = {}
    for op in hlo_mod.iter_collectives(ctx.hlo_text):
        totals[op.kind] = totals.get(op.kind, 0) + op.bytes
        sample.setdefault(op.kind, op)
    for kind, total in sorted(totals.items()):
        cap = ctx.collective_allowlist.get(kind)
        if cap is None:
            ctx.finding(
                "collective_lint",
                f"forbidden collective {kind} ({total} bytes/chip): "
                f"not in this entry's allowlist "
                f"{sorted(ctx.collective_allowlist)} | "
                f"{sample[kind].line[:120]}", None)
        elif total > cap:
            ctx.finding(
                "collective_lint",
                f"{kind} moves {total} bytes/chip, allowlist caps it at "
                f"{cap}: a param-sized operand is crossing the "
                f"interconnect | {sample[kind].line[:120]}", None)
    if totals:
        ctx.note("collectives/chip: " + ", ".join(
            f"{k}={v}B" for k, v in sorted(totals.items())))


def run_rules(ctx: RuleContext) -> EntryResult:
    """Run every registered rule (minus the entry's rules_off) over one
    context; hlo-kind rules no-op when the entry was not compiled."""
    for rule in RULES.values():
        if rule.name in ctx.rules_off:
            continue
        if rule.kind == "hlo" and ctx.hlo_text is None:
            continue
        rule.fn(ctx)
    if ctx.result.findings:
        ctx.result.status = "findings"
    return ctx.result
