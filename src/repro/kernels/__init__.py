"""Pallas TPU kernels (validated in interpret mode on CPU):
  flash_attention  sliding-window causal flash attention (long-context path)
  robust_agg       masked trimmed-mean/median over the client axis
"""
