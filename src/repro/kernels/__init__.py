"""Pallas TPU kernels (validated in interpret mode on CPU):
  flash_attention   sliding-window causal flash attention (long-context path)
  robust_agg        masked trimmed-mean/median over the client axis
  robust_pipeline   fused two-pass Eq.-11 engine: median reference + cosine
                    gate partials in one streaming pass, gated robust combine
                    in a second, cohort axis on the grid, blocked pairwise
                    distances for Krum — the core aggregation hot path.
                    Streams pytrees leaf-wise (segment-table grid, no flatten
                    concatenate) and shard-locally under shard_map (psum'd
                    partials); block size autotuned per backend
"""
