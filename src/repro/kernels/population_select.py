"""O(M) Gumbel-top-d population selection (ROADMAP item 1).

Sampling a cohort of d clients without replacement with probability
proportional to per-client weights is top-d of ``log w + Gumbel noise``
(Efraimidis-Spirakis).  The dense route — ``argsort`` of all M perturbed
keys — is O(M log M) with a full sorted-permutation materialisation,
which is exactly the wrong shape for a million-client registry sampling
a 64-client cohort.

This module implements the selection as a two-stage SEGMENTED REDUCTION,
O(M) streaming + O((M/blk) * d) merge:

  stage 1   the population streams in (blk,)-blocks; each block reduces
            to its local top-d candidates (values + global indices).
            Two interchangeable engines:
              * ``segmented`` — XLA: reshape to (M/blk, blk) and a
                batched ``lax.top_k`` per segment (the production path
                on every backend);
              * ``pallas``   — a blocked Pallas kernel, one grid step
                per segment, extracting the block top-d in VMEM by
                iterative max-and-mask (d tiny vs blk, so the extraction
                is O(blk * d) flops against one (blk,) DMA — validated
                in interpret mode on CPU like the other kernels in this
                package).
  stage 2   one ``lax.top_k`` over the (M/blk) * d surviving candidates
            — negligible next to the stream.

Every engine returns the same index set in the same (descending-key)
order — Gumbel keys are ties-free almost surely — so the round drivers
can swap engines without breaking scan==python bit parity, and the
``population_select/*`` entries of bench_kernels record all three walls
at M in {1e4, 1e5, 1e6}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

METHODS = ("argsort", "segmented", "pallas")


def _pad_neg_inf(g, blk):
    m = g.shape[0]
    pad = (-m) % blk
    if pad:
        g = jnp.concatenate([g, jnp.full((pad,), -jnp.inf, g.dtype)])
    return g, m + pad


# ---------------------------------------------------------------------------
# dense baseline: full argsort (the pre-PR behavior, kept for the bench)
# ---------------------------------------------------------------------------
def topd_argsort(g, d):
    """O(M log M) full sort baseline."""
    return jnp.argsort(-g)[:d].astype(jnp.int32)


# ---------------------------------------------------------------------------
# segmented XLA reduction
# ---------------------------------------------------------------------------
def topd_segmented(g, d, *, blk=4096):
    """Blocked two-stage top-d: per-segment ``lax.top_k`` then one merge."""
    blk = max(int(blk), d)
    g, mp = _pad_neg_inf(g.astype(jnp.float32), blk)
    nb = mp // blk
    seg = g.reshape(nb, blk)
    v, i = jax.lax.top_k(seg, d)                       # (nb, d) each
    gi = (i + (jnp.arange(nb) * blk)[:, None]).astype(jnp.int32)
    _, j = jax.lax.top_k(v.reshape(-1), d)
    return gi.reshape(-1)[j]


# ---------------------------------------------------------------------------
# Pallas blocked kernel
# ---------------------------------------------------------------------------
def _block_topd_body(x_ref, v_ref, i_ref, *, d, blk):
    """One grid step = one (blk,) population segment: extract the block's
    top-d by iterative max-and-mask entirely in VMEM, emit (d,) values +
    GLOBAL indices.  The (C,)-style running-accumulator layout of the
    robust pipeline is deliberately avoided: per-block candidates keep
    the kernel associative (a segmented reduction), so the merge can run
    anywhere and the grid steps carry no cross-step state."""
    x = x_ref[0, :].astype(jnp.float32)

    def step(carry, _):
        a = jnp.argmax(carry)
        val = carry[a]
        return carry.at[a].set(-jnp.inf), (val, a.astype(jnp.int32))

    _, (vs, ids) = jax.lax.scan(step, x, None, length=d)
    v_ref[0, :] = vs
    i_ref[0, :] = ids + jnp.int32(pl.program_id(0) * blk)


def topd_pallas(g, d, *, blk=4096, interpret=None):
    """Stage-1 candidates from the blocked Pallas kernel, stage-2 merge
    in XLA.  Off-TPU the kernel runs in interpret mode (repo test
    convention)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    blk = max(int(blk), d)
    g, mp = _pad_neg_inf(g.astype(jnp.float32), blk)
    nb = mp // blk
    v, gi = pl.pallas_call(
        functools.partial(_block_topd_body, d=d, blk=blk),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, d), lambda i: (i, 0)),
                   pl.BlockSpec((1, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, d), jnp.float32),
                   jax.ShapeDtypeStruct((nb, d), jnp.int32)],
        interpret=interpret,
    )(g.reshape(1, mp))
    _, j = jax.lax.top_k(v.reshape(-1), d)
    return gi.reshape(-1)[j]


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------
def topd(g, d, *, method="segmented", blk=4096):
    d = int(d)
    if d >= g.shape[0]:
        # degenerate cohort >= population: every client, by key order
        return jnp.argsort(-g).astype(jnp.int32)[:d]
    if method == "argsort":
        return topd_argsort(g, d)
    if method == "segmented":
        return topd_segmented(g, d, blk=blk)
    if method == "pallas":
        return topd_pallas(g, d, blk=blk)
    raise ValueError(f"unknown top-d method {method!r}; known: {METHODS}")


def gumbel_topd(logw, d, rng, *, method="segmented", blk=4096):
    """Without-replacement ∝-weights cohort sample: top-d of the
    Gumbel-perturbed log weights.  (d,) int32 population indices."""
    g = logw.astype(jnp.float32) + jax.random.gumbel(
        rng, logw.shape, jnp.float32)
    return topd(g, d, method=method, blk=blk)
