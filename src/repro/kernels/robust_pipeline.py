"""Fused two-pass robust-aggregation pipeline — Pallas TPU engine.

The trust-aware robust aggregation of paper Eq. 11 (median reference ->
gradient-cosine outlier gate -> trimmed-mean / median / weighted-mean /
Krum) as TWO streaming passes over the (G, C, N) cohort-batched client
update matrix, instead of the ~4+ independent sort-based XLA passes of
the reference path in ``core/aggregation.py``:

  pass 1   streams (C, blk) blocks once.  Per block it computes the
           coordinate-median reference with the O(C^2) stable-rank
           network (shared with kernels/robust_agg.py) AND accumulates
           the per-client cosine partials — dot(x_i, ref), ||x_i||^2,
           ||ref||^2 — into (C,) VMEM accumulators that live across the
           whole N sweep (init at block 0, revisited every block).  The
           median itself stays in VMEM: only the O(C) partials reach HBM.

  gate     resolved on-device between the passes from the (G, C)
           accumulators: O(G*C) jnp scalars, no host round-trip, no
           re-read of the update matrix.

  pass 2   streams the blocks once more, applying the gated mask (and
           the caller's trust weights for the mean modes) to emit the
           final aggregated row: trimmed mean / median via the rank
           network, or the normalised weighted mean.

  krum     an extra blocked pairwise-distance kernel accumulates the
           (C, C) Gram matrix in one more streaming pass; the O(C^2)
           Krum scoring runs on-device in jnp and the winners are
           averaged by pass 2 in ``mean`` mode.

The leading G (cohort) grid axis batches every slot of the two-stage
scheme in ONE ``pallas_call`` — the reference's per-cohort Python loop
becomes a grid dimension.

HBM traffic: the reference path reads (and for sorts, re-writes) the
(C, N) matrix >= 4 times; the fused pipeline reads it exactly twice
(three times for Krum) and writes only the (1, N) output.  See
``benchmarks/bench_kernels.py::robust_pipeline_roofline``.  Caveat: the
pytree wrappers below flatten multi-leaf trees with one concatenate
(plus a pad when N % blk != 0), which materialises an extra (C, N)
copy before the kernel — streaming the passes leaf-wise to avoid that
copy is a ROADMAP follow-up.

Layout note: the (C,)-shaped accumulators use C as the minor dimension;
on real TPUs C < 128 relies on Mosaic's small-array padding.  The pipeline
is validated in interpret mode on CPU (the repo's test substrate); ``blk``
should be large there so the grid stays short.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.robust_agg import _BIG, stable_ranks


def _on_tpu():
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# pass 1: median reference + cosine-gate partials
# ---------------------------------------------------------------------------

def _pass1_body(n_ref, x_ref, mask_ref, dot_ref, sqn_ref, refsq_ref, *, c):
    g = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)              # (C, blk)
    m = mask_ref[0].astype(jnp.float32)           # (C, 1)
    n = n_ref[g].astype(jnp.float32)

    xm = jnp.where(m > 0, x, _BIG)
    rank = stable_ranks(xm, c)                    # (C, blk)
    lo = jnp.floor((n - 1.0) / 2.0)
    hi = jnp.ceil((n - 1.0) / 2.0)
    pick_lo = (rank == lo).astype(jnp.float32) * m
    pick_hi = (rank == hi).astype(jnp.float32) * m
    # median reference lives only in VMEM: consumed by the partials below,
    # never written to HBM (pass 2 recomputes it from the rank network)
    med = 0.5 * ((x * pick_lo).sum(axis=0, keepdims=True)
                 + (x * pick_hi).sum(axis=0, keepdims=True))   # (1, blk)

    @pl.when(i == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)
        refsq_ref[...] = jnp.zeros_like(refsq_ref)

    dot_ref[...] += (x * med).sum(axis=1)[None, :]
    sqn_ref[...] += (x * x).sum(axis=1)[None, :]
    refsq_ref[...] += (med * med).sum(axis=1, keepdims=True)


def cosine_gate_partials(x, mask, *, blk=4096, interpret=False):
    """x: (G, C, N) f32, mask: (G, C) 0/1 ->
    (dots (G, C), sqnorms (G, C), refsq (G, 1)) — the per-client cosine
    partials vs the coordinate-median reference, in one streaming read."""
    G, C, N = x.shape
    assert N % blk == 0, (N, blk)
    n_sel = mask.sum(axis=1).astype(jnp.float32)  # (G,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, N // blk),
        in_specs=[
            pl.BlockSpec((1, C, blk), lambda g, i, n: (g, 0, i)),
            pl.BlockSpec((1, C, 1), lambda g, i, n: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda g, i, n: (g, 0)),
            pl.BlockSpec((1, C), lambda g, i, n: (g, 0)),
            pl.BlockSpec((1, 1), lambda g, i, n: (g, 0)),
        ],
    )
    dots, sqn, refsq = pl.pallas_call(
        functools.partial(_pass1_body, c=C),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(n_sel, x, mask.reshape(G, C, 1))
    return dots, sqn, refsq


# ---------------------------------------------------------------------------
# pass 2: gated robust combine
# ---------------------------------------------------------------------------

def _pass2_body(n_ref, x_ref, m_ref, w_ref, o_ref, *, c, mode, trim_frac):
    g = pl.program_id(0)
    x = x_ref[0].astype(jnp.float32)              # (C, blk)
    m = m_ref[0].astype(jnp.float32)              # (C, 1)

    if mode == "mean":
        w = w_ref[0].astype(jnp.float32)          # (C, 1) pre-normalised
        o_ref[0] = (x * w).sum(axis=0, keepdims=True).astype(o_ref.dtype)
        return

    n = n_ref[g].astype(jnp.float32)
    xm = jnp.where(m > 0, x, _BIG)
    rank = stable_ranks(xm, c)
    if mode == "trimmed":
        t = jnp.floor(trim_frac * n)
        keep = ((rank >= t) & (rank < n - t)).astype(jnp.float32) * m
        cnt = jnp.maximum(n - 2.0 * t, 1.0)
        o_ref[0] = ((x * keep).sum(axis=0, keepdims=True) / cnt
                    ).astype(o_ref.dtype)
    else:                                          # median
        lo = jnp.floor((n - 1.0) / 2.0)
        hi = jnp.ceil((n - 1.0) / 2.0)
        pick_lo = (rank == lo).astype(jnp.float32) * m
        pick_hi = (rank == hi).astype(jnp.float32) * m
        o_ref[0] = (0.5 * ((x * pick_lo).sum(axis=0, keepdims=True)
                           + (x * pick_hi).sum(axis=0, keepdims=True))
                    ).astype(o_ref.dtype)


def gated_combine(x, gated_mask, weights, *, mode, trim_frac=0.2, blk=4096,
                  interpret=False):
    """x: (G, C, N); gated_mask: (G, C); weights: (G, C) (normalised,
    ``mean`` mode only) -> (G, N)."""
    G, C, N = x.shape
    assert N % blk == 0, (N, blk)
    n_sel = gated_mask.sum(axis=1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, N // blk),
        in_specs=[
            pl.BlockSpec((1, C, blk), lambda g, i, n: (g, 0, i)),
            pl.BlockSpec((1, C, 1), lambda g, i, n: (g, 0, 0)),
            pl.BlockSpec((1, C, 1), lambda g, i, n: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk), lambda g, i, n: (g, 0, i)),
    )
    out = pl.pallas_call(
        functools.partial(_pass2_body, c=C, mode=mode, trim_frac=trim_frac),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, 1, N), jnp.float32),
        interpret=interpret,
    )(n_sel, x, gated_mask.reshape(G, C, 1), weights.reshape(G, C, 1))
    return out[:, 0]


# ---------------------------------------------------------------------------
# blocked pairwise distances (Krum)
# ---------------------------------------------------------------------------

def _pairwise_body(x_ref, gram_ref, sqn_ref, *, c):
    i = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)              # (C, blk)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)

    gram_ref[0] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    sqn_ref[...] += (x * x).sum(axis=1)[None, :]


def pairwise_sq_dists_blocked(x, mask, *, blk=4096, interpret=False):
    """Blocked (G, C, C) squared distances: streams N once, accumulating
    the Gram matrix and row norms; masked-out pairs pushed to +_BIG (same
    contract as ``aggregation.pairwise_sq_dists``)."""
    G, C, N = x.shape
    assert N % blk == 0, (N, blk)
    gram, sqn = pl.pallas_call(
        functools.partial(_pairwise_body, c=C),
        grid=(G, N // blk),
        in_specs=[pl.BlockSpec((1, C, blk), lambda g, i: (g, 0, i))],
        out_specs=[
            pl.BlockSpec((1, C, C), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, C), lambda g, i: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, C, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    d = sqn[:, :, None] + sqn[:, None, :] - 2.0 * gram
    big = _BIG * (1.0 - mask[:, :, None] * mask[:, None, :])
    return jnp.maximum(d, 0.0) + big


def _krum_weights(d, mask, f, multi_m):
    """Krum selection weights from (G, C, C) distances; mirrors
    ``aggregation.krum`` (scores = sum of n-f-2 smallest distances,
    multi_m best averaged)."""
    G, C, _ = d.shape
    d = d + _BIG * jnp.eye(C)[None]               # exclude self
    n = mask.sum(axis=1, keepdims=True)           # (G, 1)
    closest = jnp.sort(d, axis=2)
    j = jnp.arange(C, dtype=jnp.float32)[None, None, :]
    take = jnp.maximum(n - f - 2, 1.0)[:, :, None]
    scores = jnp.where(j < take, closest, 0.0).sum(axis=2)    # (G, C)
    scores = jnp.where(mask > 0, scores, _BIG)
    pos = jnp.argsort(jnp.argsort(scores, axis=1), axis=1)
    sel = (pos < multi_m).astype(jnp.float32)
    return sel / jnp.maximum(sel.sum(axis=1, keepdims=True), 1e-12)


# ---------------------------------------------------------------------------
# the fused pipeline
# ---------------------------------------------------------------------------

def fused_pipeline(x, weights, mask, *, aggregator="trimmed_mean",
                   trim_frac=0.2, cosine_thresh=-0.5, krum_f=1,
                   krum_multi_m=1, blk=4096, interpret=None):
    """Full Eq.-11 pipeline over a cohort batch.

    x: (G, C, N) f32 flattened client updates; weights, mask: (G, C).
    Returns the (G, N) aggregated rows.  Semantically equivalent to
    ``aggregation.aggregate_ref`` vmapped over G (parity-tested)."""
    G, C, N = x.shape
    if interpret is None:
        interpret = not _on_tpu()
    blk = min(blk, max(128, N))
    pad = (-N) % blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
    x = x.astype(jnp.float32)
    mask = mask.astype(jnp.float32)

    # ---- pass 1: median reference + cosine partials (1 read of x) ----
    dots, sqn, refsq = cosine_gate_partials(
        x, mask, blk=blk, interpret=interpret)

    # ---- on-device gate resolution: O(G*C) scalars ----
    cos = dots / jnp.maximum(jnp.sqrt(sqn * refsq), 1e-12)
    gate = ((cos >= cosine_thresh) & (mask > 0)).astype(jnp.float32)
    m = mask * gate
    m = jnp.where(m.sum(axis=1, keepdims=True) > 0, m, mask)  # never empty

    # ---- pass 2 (+ Krum distance pass): gated combine ----
    if aggregator == "fedavg":
        w = weights * m
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-12)
        out = gated_combine(x, m, w, mode="mean", blk=blk,
                            interpret=interpret)
    elif aggregator == "trimmed_mean":
        out = gated_combine(x, m, m, mode="trimmed", trim_frac=trim_frac,
                            blk=blk, interpret=interpret)
    elif aggregator == "median":
        out = gated_combine(x, m, m, mode="median", blk=blk,
                            interpret=interpret)
    elif aggregator == "krum":
        d = pairwise_sq_dists_blocked(x, m, blk=blk, interpret=interpret)
        w = _krum_weights(d, m, krum_f, krum_multi_m)
        out = gated_combine(x, m, w, mode="mean", blk=blk,
                            interpret=interpret)
    else:
        raise ValueError(aggregator)
    return out[:, :N] if pad else out


# ---------------------------------------------------------------------------
# pytree wrappers (the core/aggregation.py hot path)
# ---------------------------------------------------------------------------

def _flatten_cohorts(updates, lead):
    """Flatten a pytree of (*lead, ...) leaves into one (*lead, N) f32
    matrix; returns (flat, treedef, leaves, sizes)."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    sizes = [int(l.size // max(1, _prod(l.shape[:lead]))) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(*l.shape[:lead], -1).astype(jnp.float32) for l in leaves],
        axis=-1)
    return flat, treedef, leaves, sizes


def _prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def _unflatten(agg, treedef, leaves, sizes, lead):
    out, off = [], 0
    for l, n in zip(leaves, sizes):
        out.append(agg[..., off:off + n].reshape(l.shape[lead:]).astype(
            l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


@functools.partial(jax.jit, static_argnames=("cfg", "blk", "interpret"))
def fused_aggregate_tree(updates, weights, mask, cfg, *, blk=4096,
                         interpret=None):
    """Single-cohort Eq.-11 aggregation over a pytree of (C, ...) leaves;
    drop-in for ``aggregation.aggregate_ref`` (which stays as the parity
    oracle)."""
    flat, treedef, leaves, sizes = _flatten_cohorts(updates, 1)
    out = fused_pipeline(
        flat[None], weights[None], mask[None],
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk, interpret=interpret)[0]
    return _unflatten(out, treedef, leaves, sizes, 1)


@functools.partial(jax.jit, static_argnames=("cfg", "blk", "interpret"))
def fused_two_stage_tree(slot_updates, slot_weights, slot_masks, cfg, *,
                         blk=4096, interpret=None):
    """Cohort-batched two-stage scheme: every slot rides the G grid axis of
    ONE fused pipeline call (the reference's per-cohort Python loop becomes
    a grid dimension), then the cross-slot size-weighted mean."""
    flat, treedef, leaves, sizes = _flatten_cohorts(slot_updates, 2)
    per = fused_pipeline(
        flat, slot_weights, slot_masks,
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk, interpret=interpret)                      # (G, N)
    cw = slot_masks.sum(axis=1).astype(jnp.float32)
    cw = cw / jnp.maximum(cw.sum(), 1e-12)
    combined = jnp.tensordot(cw, per, axes=(0, 0))         # (N,)
    return _unflatten(combined, treedef, leaves, sizes, 2)
