"""Fused two-pass robust-aggregation pipeline — Pallas TPU engine.

The trust-aware robust aggregation of paper Eq. 11 (median reference ->
gradient-cosine outlier gate -> trimmed-mean / median / weighted-mean /
Krum) as TWO streaming passes over the cohort-batched client update
matrix, instead of the ~4+ independent sort-based XLA passes of the
reference path in ``core/aggregation.py``:

  pass 1   streams (C, blk) blocks once.  Per block it computes the
           coordinate-median reference with the O(C^2) stable-rank
           network (shared with kernels/robust_agg.py) AND accumulates
           the per-client cosine partials — dot(x_i, ref), ||x_i||^2,
           ||ref||^2 — into (C,) VMEM accumulators that live across the
           whole N sweep (init at block 0, revisited every block).  The
           median itself stays in VMEM: only the O(C) partials reach HBM.

  gate     resolved on-device between the passes from the (G, C)
           accumulators: O(G*C) jnp scalars, no host round-trip, no
           re-read of the update matrix.

  pass 2   streams the blocks once more, applying the gated mask (and
           the caller's trust weights for the mean modes) to emit the
           final aggregated row: trimmed mean / median via the rank
           network, or the normalised weighted mean.

  krum     an extra blocked pairwise-distance kernel accumulates the
           (C, C) Gram matrix in one more streaming pass; the O(C^2)
           Krum scoring runs on-device in jnp and the winners are
           averaged by pass 2 in ``mean`` mode.

The leading G (cohort) grid axis batches every slot of the two-stage
scheme in ONE ``pallas_call`` — the reference's per-cohort Python loop
becomes a grid dimension.

Leaf streaming (this PR): multi-leaf pytrees no longer flatten through a
(C, N) ``concatenate``.  A *segment-offset table* (static, derived from
the leaf sizes and ``blk``) assigns each leaf a contiguous run of grid
steps; both passes are ONE ``pallas_call`` whose per-leaf BlockSpec
index maps clamp into the leaf's segment, so each leaf block is DMA'd
exactly once and the (C,) dot/norm/gate accumulators are SHARED across
all segments in VMEM.  Leaves stream in place (a reshape view, no copy);
ragged tails are masked in-kernel, accumulation is fp32 throughout, and
each leaf is cast back to its own dtype exactly once — by the pass-2
output write.  The 2-pass HBM roofline is therefore end-to-end: no
flatten concatenate, no unflatten slice-copy.  (The PR-1 flatten path is
kept below as ``*_flat`` — the bench baseline and a parity oracle.)

Distribution hooks: ``fused_pipeline_leafwise`` takes ``axis_name`` +
``leaf_scale`` so ``aggregation.aggregate_sharded`` can run the passes
shard-locally under ``shard_map`` — only the (C,) cosine partials (and
Krum's Gram matrix) cross devices, in one ``psum``.

HBM traffic: the reference path reads (and for sorts, re-writes) the
(C, N) matrix >= 4 times; the fused pipeline reads it exactly twice
(three times for Krum) and writes only the (1, N) output.  See
``benchmarks/bench_kernels.py::robust_pipeline_roofline``.

Layout note: the (C,)-shaped accumulators use C as the minor dimension;
on real TPUs C < 128 relies on Mosaic's small-array padding.  The
pipeline is validated in interpret mode on CPU (the repo's test
substrate); ``auto_blk`` keeps grids short there and VMEM-sized on TPU.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.robust_agg import _BIG, stable_ranks


def _on_tpu():
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# segment-offset table + block autotune
# ---------------------------------------------------------------------------

class _Seg(NamedTuple):
    """One leaf's contiguous run of grid steps: steps [start, start +
    nblocks) stream its (C, n) matrix in (C, blk) blocks.  ``blk`` is
    per-leaf: a leaf narrower than the pipeline block gets a 128-aligned
    block of its own width, so small norm/bias leaves don't pay a full
    rank-network block of padding."""
    start: int
    nblocks: int
    n: int
    blk: int


def make_segments(sizes, blk):
    """Static segment-offset table mapping grid steps to (leaf, block).

    Leaves that need several blocks get sequential step runs; leaves that
    fit ONE block all share step 0 (their block index is constant, so
    they cost no extra DMA and no extra grid steps — on a single-block
    tree the whole pass collapses to one step per cohort).  Segments may
    therefore overlap: a step computes every leaf whose run covers it.
    """
    segs, start = [], 0
    for n in sizes:
        b = min(blk, _round_up(int(n), 128))
        nb = max(1, -(-int(n) // b))
        if nb == 1:
            segs.append(_Seg(0, 1, int(n), b))
        else:
            segs.append(_Seg(start, nb, int(n), b))
            start += nb
    return tuple(segs), max(1, start)


def _round_up(x, m):
    return -(-x // m) * m


def auto_blk(c, sizes, *, backend=None):
    """Pick the streaming block size from the backend + memory budget.

    CPU interpret: the rank network materialises (C, C, blk) f32
    intermediates, so blocks are sized to keep that working set inside
    the last-level cache (~16 MB — measured 2x wall time when it spills)
    while staying large enough to amortise the per-step interpreter
    overhead: clamp to [2048, 32768] lanes, and never wider than the
    longest leaf.  TPU: VMEM-sized tiles — each live (C, blk) f32 leaf
    block is double-buffered and the rank network needs its (C, C, blk)
    scratch, so blocks fit an ~8 MB budget, clamped to [512, 8192] lanes
    (multiples of 128).
    """
    if backend is None:
        backend = jax.default_backend()
    if backend == "tpu":
        budget = 8 * 2 ** 20
        blk = budget // (4 * max(c, 8) * (max(c, 8) + 2))
        return int(max(512, min(_round_up(blk, 128), 8192)))
    budget = 16 * 2 ** 20
    blk = budget // (4 * max(c, 8) ** 2)
    blk = max(2048, min(_round_up(blk, 128), 1 << 15))
    return int(min(blk, _round_up(max(sizes), 128)))


def _seg_index_map(seg):
    """Clamped per-leaf BlockSpec index map: outside the leaf's segment the
    block index pins to the segment edge, so no re-DMA happens on the
    off-segment steps (the scalar-prefetch refs arrive as trailing args)."""
    return lambda g, i, *_: (g, 0, jnp.clip(i - seg.start, 0,
                                            seg.nblocks - 1))


def _foreach_active_leaf(segs, total, i, fn):
    """Run ``fn(l, seg)`` for every leaf whose segment covers step ``i``.
    A segment spanning the WHOLE grid (the collapsed single-step layout
    ``make_segments`` emits on short grids) runs unconditionally — the
    ``pl.when`` cond would otherwise fence XLA's fusion of the rank
    network in interpret mode (~30% wall time on CPU)."""
    for l, seg in enumerate(segs):
        if seg.start == 0 and seg.nblocks >= total:
            fn(l, seg)
        else:
            pl.when((i >= seg.start) & (i < seg.start + seg.nblocks))(
                functools.partial(fn, l, seg))


def _leaf_block(x_refs, l, seg, i):
    """Load leaf ``l``'s current (C, seg.blk) block in fp32 with the
    ragged tail masked to zero (OOB lanes of the overrunning last block
    carry unspecified values; ``where`` keeps them out of every
    accumulator).  ``i`` is the step index, read by the caller at kernel
    top level — ``pl.program_id`` inside a ``pl.when`` branch is not
    substituted by the interpreter."""
    x = x_refs[l][0].astype(jnp.float32)
    if seg.n % seg.blk:
        valid = seg.n - (i - seg.start) * seg.blk
        col = jax.lax.broadcasted_iota(jnp.int32, (1, seg.blk), 1)
        x = jnp.where(col < valid, x, 0.0)
    return x


# ---------------------------------------------------------------------------
# pass 1: median reference + cosine-gate partials
# ---------------------------------------------------------------------------

def _median_block(x, m, n, c):
    """Coordinate-median of an fp32 (C, blk) block via the rank network;
    stays in VMEM (consumed by the partials, recomputed by pass 2)."""
    xm = jnp.where(m > 0, x, _BIG)
    rank = stable_ranks(xm, c)
    lo = jnp.floor((n - 1.0) / 2.0)
    hi = jnp.ceil((n - 1.0) / 2.0)
    pick_lo = (rank == lo).astype(jnp.float32) * m
    pick_hi = (rank == hi).astype(jnp.float32) * m
    return 0.5 * ((x * pick_lo).sum(axis=0, keepdims=True)
                  + (x * pick_hi).sum(axis=0, keepdims=True))   # (1, blk)


def _pass1_body(n_ref, x_ref, mask_ref, dot_ref, sqn_ref, refsq_ref, *, c):
    g = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)              # (C, blk)
    m = mask_ref[0].astype(jnp.float32)           # (C, 1)
    n = n_ref[g].astype(jnp.float32)
    med = _median_block(x, m, n, c)

    @pl.when(i == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)
        refsq_ref[...] = jnp.zeros_like(refsq_ref)

    dot_ref[...] += (x * med).sum(axis=1)[None, :]
    sqn_ref[...] += (x * x).sum(axis=1)[None, :]
    refsq_ref[...] += (med * med).sum(axis=1, keepdims=True)


def cosine_gate_partials(x, mask, *, blk=4096, interpret=False):
    """x: (G, C, N) f32, mask: (G, C) 0/1 ->
    (dots (G, C), sqnorms (G, C), refsq (G, 1)) — the per-client cosine
    partials vs the coordinate-median reference, in one streaming read."""
    G, C, N = x.shape
    assert N % blk == 0, (N, blk)
    n_sel = mask.sum(axis=1).astype(jnp.float32)  # (G,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, N // blk),
        in_specs=[
            pl.BlockSpec((1, C, blk), lambda g, i, n: (g, 0, i)),
            pl.BlockSpec((1, C, 1), lambda g, i, n: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda g, i, n: (g, 0)),
            pl.BlockSpec((1, C), lambda g, i, n: (g, 0)),
            pl.BlockSpec((1, 1), lambda g, i, n: (g, 0)),
        ],
    )
    dots, sqn, refsq = pl.pallas_call(
        functools.partial(_pass1_body, c=C),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(n_sel, x, mask.reshape(G, C, 1))
    return dots, sqn, refsq


def _pass1_leaf_body(n_ref, scale_ref, *refs, segs, total, c):
    L = len(segs)
    x_refs = refs[:L]
    mask_ref = refs[L]
    dot_ref, sqn_ref, refsq_ref = refs[L + 1:]
    g = pl.program_id(0)
    i = pl.program_id(1)
    m = mask_ref[0].astype(jnp.float32)           # (C, 1)
    n = n_ref[g].astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)
        refsq_ref[...] = jnp.zeros_like(refsq_ref)

    def accumulate(l, seg):
        x = _leaf_block(x_refs, l, seg, i)
        med = _median_block(x, m, n, c)
        s = scale_ref[l]
        dot_ref[...] += s * (x * med).sum(axis=1)[None, :]
        sqn_ref[...] += s * (x * x).sum(axis=1)[None, :]
        refsq_ref[...] += s * (med * med).sum(axis=1, keepdims=True)

    _foreach_active_leaf(segs, total, i, accumulate)


def cosine_gate_partials_leafwise(leaves, mask, *, blk, leaf_scale,
                                  interpret=False):
    """Segment-table pass 1: leaves [(G, C, n_l)] stream through ONE
    ``pallas_call`` sharing the (C,) accumulators across all segments.
    ``leaf_scale`` (L,) scales each leaf's contribution (1.0 everywhere
    off-mesh; under ``shard_map`` it de-duplicates replicated leaves
    before the cross-device psum)."""
    G, C = leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in leaves)
    segs, total = make_segments(sizes, blk)
    n_sel = mask.sum(axis=1).astype(jnp.float32)  # (G,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, total),
        in_specs=[pl.BlockSpec((1, C, seg.blk), _seg_index_map(seg))
                  for seg in segs]
        + [pl.BlockSpec((1, C, 1), lambda g, i, *_: (g, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, C), lambda g, i, *_: (g, 0)),
            pl.BlockSpec((1, C), lambda g, i, *_: (g, 0)),
            pl.BlockSpec((1, 1), lambda g, i, *_: (g, 0)),
        ],
    )
    dots, sqn, refsq = pl.pallas_call(
        functools.partial(_pass1_leaf_body, segs=segs, total=total, c=C),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
            jax.ShapeDtypeStruct((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(n_sel, leaf_scale, *leaves, mask.reshape(G, C, 1))
    return dots, sqn, refsq


# ---------------------------------------------------------------------------
# pass 2: gated robust combine
# ---------------------------------------------------------------------------

def _combine_block(x, m, w, n, *, c, mode, trim_frac):
    """One (C, blk) -> (1, blk) gated combine in fp32."""
    if mode == "mean":
        return (x * w).sum(axis=0, keepdims=True)
    xm = jnp.where(m > 0, x, _BIG)
    rank = stable_ranks(xm, c)
    if mode == "trimmed":
        t = jnp.floor(trim_frac * n)
        keep = ((rank >= t) & (rank < n - t)).astype(jnp.float32) * m
        cnt = jnp.maximum(n - 2.0 * t, 1.0)
        return (x * keep).sum(axis=0, keepdims=True) / cnt
    # median
    lo = jnp.floor((n - 1.0) / 2.0)
    hi = jnp.ceil((n - 1.0) / 2.0)
    pick_lo = (rank == lo).astype(jnp.float32) * m
    pick_hi = (rank == hi).astype(jnp.float32) * m
    return 0.5 * ((x * pick_lo).sum(axis=0, keepdims=True)
                  + (x * pick_hi).sum(axis=0, keepdims=True))


def _pass2_body(n_ref, x_ref, m_ref, w_ref, o_ref, *, c, mode, trim_frac):
    g = pl.program_id(0)
    x = x_ref[0].astype(jnp.float32)              # (C, blk)
    m = m_ref[0].astype(jnp.float32)              # (C, 1)
    w = w_ref[0].astype(jnp.float32)              # (C, 1) pre-normalised
    n = n_ref[g].astype(jnp.float32)
    o_ref[0] = _combine_block(x, m, w, n, c=c, mode=mode,
                              trim_frac=trim_frac).astype(o_ref.dtype)


def gated_combine(x, gated_mask, weights, *, mode, trim_frac=0.2, blk=4096,
                  interpret=False):
    """x: (G, C, N); gated_mask: (G, C); weights: (G, C) (normalised,
    ``mean`` mode only) -> (G, N)."""
    G, C, N = x.shape
    assert N % blk == 0, (N, blk)
    n_sel = gated_mask.sum(axis=1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, N // blk),
        in_specs=[
            pl.BlockSpec((1, C, blk), lambda g, i, n: (g, 0, i)),
            pl.BlockSpec((1, C, 1), lambda g, i, n: (g, 0, 0)),
            pl.BlockSpec((1, C, 1), lambda g, i, n: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk), lambda g, i, n: (g, 0, i)),
    )
    out = pl.pallas_call(
        functools.partial(_pass2_body, c=C, mode=mode, trim_frac=trim_frac),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, 1, N), jnp.float32),
        interpret=interpret,
    )(n_sel, x, gated_mask.reshape(G, C, 1), weights.reshape(G, C, 1))
    return out[:, 0]


def _pass2_leaf_body(n_ref, *refs, segs, total, c, mode, trim_frac):
    L = len(segs)
    x_refs = refs[:L]
    m_ref, w_ref = refs[L], refs[L + 1]
    o_refs = refs[L + 2:]
    g = pl.program_id(0)
    i = pl.program_id(1)
    m = m_ref[0].astype(jnp.float32)              # (C, 1)
    w = w_ref[0].astype(jnp.float32)              # (C, 1)
    n = n_ref[g].astype(jnp.float32)

    def emit(l, seg):
        x = _leaf_block(x_refs, l, seg, i)
        o_refs[l][0] = _combine_block(
            x, m, w, n, c=c, mode=mode, trim_frac=trim_frac
        ).astype(o_refs[l].dtype)

    _foreach_active_leaf(segs, total, i, emit)


def gated_combine_leafwise(leaves, gated_mask, weights, *, mode,
                           trim_frac=0.2, blk, out_dtypes, interpret=False):
    """Segment-table pass 2: per-leaf (G, n_l) outputs, each written in its
    own ``out_dtypes[l]`` — the single fp32->leaf-dtype cast of the whole
    pipeline happens at this output write."""
    G, C = leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in leaves)
    segs, total = make_segments(sizes, blk)
    n_sel = gated_mask.sum(axis=1).astype(jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, total),
        in_specs=[pl.BlockSpec((1, C, seg.blk), _seg_index_map(seg))
                  for seg in segs]
        + [pl.BlockSpec((1, C, 1), lambda g, i, *_: (g, 0, 0)),
           pl.BlockSpec((1, C, 1), lambda g, i, *_: (g, 0, 0))],
        out_specs=[pl.BlockSpec((1, 1, seg.blk), _seg_index_map(seg))
                   for seg in segs],
    )
    outs = pl.pallas_call(
        functools.partial(_pass2_leaf_body, segs=segs, total=total, c=C,
                          mode=mode, trim_frac=trim_frac),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((G, 1, seg.n), dt)
                   for seg, dt in zip(segs, out_dtypes)],
        interpret=interpret,
    )(n_sel, *leaves, gated_mask.reshape(G, C, 1), weights.reshape(G, C, 1))
    return [o[:, 0] for o in outs]


# ---------------------------------------------------------------------------
# blocked pairwise distances (Krum)
# ---------------------------------------------------------------------------

def _pairwise_body(x_ref, gram_ref, sqn_ref, *, c):
    i = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)              # (C, blk)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)

    gram_ref[0] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    sqn_ref[...] += (x * x).sum(axis=1)[None, :]


def pairwise_sq_dists_blocked(x, mask, *, blk=4096, interpret=False):
    """Blocked (G, C, C) squared distances: streams N once, accumulating
    the Gram matrix and row norms; masked-out pairs pushed to +_BIG (same
    contract as ``aggregation.pairwise_sq_dists``)."""
    G, C, N = x.shape
    assert N % blk == 0, (N, blk)
    gram, sqn = pl.pallas_call(
        functools.partial(_pairwise_body, c=C),
        grid=(G, N // blk),
        in_specs=[pl.BlockSpec((1, C, blk), lambda g, i: (g, 0, i))],
        out_specs=[
            pl.BlockSpec((1, C, C), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((1, C), lambda g, i: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, C, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    d = sqn[:, :, None] + sqn[:, None, :] - 2.0 * gram
    big = _BIG * (1.0 - mask[:, :, None] * mask[:, None, :])
    return jnp.maximum(d, 0.0) + big


def _pairwise_leaf_body(scale_ref, *refs, segs, total, c):
    L = len(segs)
    x_refs = refs[:L]
    gram_ref, sqn_ref = refs[L:]
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        sqn_ref[...] = jnp.zeros_like(sqn_ref)

    def accumulate(l, seg):
        x = _leaf_block(x_refs, l, seg, i)
        s = scale_ref[l]
        gram_ref[0] += s * jax.lax.dot_general(
            x, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        sqn_ref[...] += s * (x * x).sum(axis=1)[None, :]

    _foreach_active_leaf(segs, total, i, accumulate)


def pairwise_sq_dists_leafwise(leaves, mask, *, blk, leaf_scale,
                               interpret=False, axis_name=None):
    """Segment-table Krum distance pass: Gram + row norms accumulate across
    all leaf segments in one streaming read; under ``shard_map`` the (C, C)
    Gram matrix (not the update matrix) is what crosses devices."""
    G, C = leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in leaves)
    segs, total = make_segments(sizes, blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G, total),
        in_specs=[pl.BlockSpec((1, C, seg.blk), _seg_index_map(seg))
                  for seg in segs],
        out_specs=[
            pl.BlockSpec((1, C, C), lambda g, i, *_: (g, 0, 0)),
            pl.BlockSpec((1, C), lambda g, i, *_: (g, 0)),
        ],
    )
    gram, sqn = pl.pallas_call(
        functools.partial(_pairwise_leaf_body, segs=segs, total=total, c=C),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, C, C), jnp.float32),
            jax.ShapeDtypeStruct((G, C), jnp.float32),
        ],
        interpret=interpret,
    )(leaf_scale, *leaves)
    if axis_name is not None:
        gram = jax.lax.psum(gram, axis_name)
        sqn = jax.lax.psum(sqn, axis_name)
    d = sqn[:, :, None] + sqn[:, None, :] - 2.0 * gram
    big = _BIG * (1.0 - mask[:, :, None] * mask[:, None, :])
    return jnp.maximum(d, 0.0) + big


def _krum_weights(d, mask, f, multi_m):
    """Krum selection weights from (G, C, C) distances; mirrors
    ``aggregation.krum`` (scores = sum of n-f-2 smallest distances,
    multi_m best averaged)."""
    G, C, _ = d.shape
    d = d + _BIG * jnp.eye(C)[None]               # exclude self
    n = mask.sum(axis=1, keepdims=True)           # (G, 1)
    closest = jnp.sort(d, axis=2)
    j = jnp.arange(C, dtype=jnp.float32)[None, None, :]
    take = jnp.maximum(n - f - 2, 1.0)[:, :, None]
    scores = jnp.where(j < take, closest, 0.0).sum(axis=2)    # (G, C)
    # inf (not _BIG) so a lone selected client (score _BIG + d, from
    # distances to masked peers) still outranks the excluded rows
    scores = jnp.where(mask > 0, scores, jnp.inf)
    pos = jnp.argsort(jnp.argsort(scores, axis=1), axis=1)
    # winners restricted to masked-in clients: an empty cohort row (all
    # scores tied at _BIG) must produce zero weights, not an arbitrary
    # client's update (mirrors aggregation.krum's empty-cohort guard)
    sel = (pos < multi_m).astype(jnp.float32) * (mask > 0)
    return sel / jnp.maximum(sel.sum(axis=1, keepdims=True), 1e-12)


# ---------------------------------------------------------------------------
# the fused pipeline — flat (single pre-flattened matrix) and leafwise
# ---------------------------------------------------------------------------

def fused_pipeline(x, weights, mask, *, aggregator="trimmed_mean",
                   trim_frac=0.2, cosine_thresh=-0.5, krum_f=1,
                   krum_multi_m=1, blk=4096, interpret=None):
    """Full Eq.-11 pipeline over a cohort batch of ONE pre-flattened
    matrix.

    x: (G, C, N) f32 flattened client updates; weights, mask: (G, C).
    Returns the (G, N) aggregated rows.  Semantically equivalent to
    ``aggregation.aggregate_ref`` vmapped over G (parity-tested)."""
    G, C, N = x.shape
    if interpret is None:
        interpret = not _on_tpu()
    blk = min(blk, max(128, N))
    pad = (-N) % blk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
    x = x.astype(jnp.float32)
    mask = mask.astype(jnp.float32)

    # ---- pass 1: median reference + cosine partials (1 read of x) ----
    dots, sqn, refsq = cosine_gate_partials(
        x, mask, blk=blk, interpret=interpret)

    # ---- on-device gate resolution: O(G*C) scalars ----
    m = _resolve_gate(dots, sqn, refsq, mask, cosine_thresh)

    # ---- pass 2 (+ Krum distance pass): gated combine ----
    if aggregator == "fedavg":
        w = weights * m
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-12)
        out = gated_combine(x, m, w, mode="mean", blk=blk,
                            interpret=interpret)
    elif aggregator == "trimmed_mean":
        out = gated_combine(x, m, m, mode="trimmed", trim_frac=trim_frac,
                            blk=blk, interpret=interpret)
    elif aggregator == "median":
        out = gated_combine(x, m, m, mode="median", blk=blk,
                            interpret=interpret)
    elif aggregator == "krum":
        d = pairwise_sq_dists_blocked(x, m, blk=blk, interpret=interpret)
        w = _krum_weights(d, m, krum_f, krum_multi_m)
        out = gated_combine(x, m, w, mode="mean", blk=blk,
                            interpret=interpret)
    else:
        raise ValueError(aggregator)
    return out[:, :N] if pad else out


def _resolve_gate(dots, sqn, refsq, mask, cosine_thresh):
    """Cosine outlier gate from the pass-1 partials; never gates everyone
    out. O(G*C) scalars, on-device.  An INCOMING all-zero mask row passes
    through unchanged — every pass-2 combine mode then emits a zero row
    for that cohort (the kernels mask by ``m``), matching the reference
    path's empty-cohort semantics."""
    cos = dots / jnp.maximum(jnp.sqrt(sqn * refsq), 1e-12)
    gate = ((cos >= cosine_thresh) & (mask > 0)).astype(jnp.float32)
    m = mask * gate
    return jnp.where(m.sum(axis=1, keepdims=True) > 0, m, mask)


def fused_pipeline_leafwise(leaves, weights, mask, *,
                            aggregator="trimmed_mean", trim_frac=0.2,
                            cosine_thresh=-0.5, krum_f=1, krum_multi_m=1,
                            blk=None, interpret=None, axis_name=None,
                            leaf_scale=None, out_dtypes=None):
    """Full Eq.-11 pipeline over a LIST of (G, C, n_l) leaf matrices —
    the segment-table passes stream every leaf in place (no concatenate).

    Returns the per-leaf (G, n_l) aggregated rows in ``out_dtypes``
    (default fp32; pass leaf dtypes for the single end-of-pipe cast).

    Distribution: under ``shard_map`` pass ``axis_name`` (mesh axis name
    or tuple) so the (C,) cosine partials and Krum's Gram matrix psum
    across devices, and ``leaf_scale`` (L,) with 0/1 entries that keep
    replicated (non-divisible) leaves from being double-counted."""
    G, C = leaves[0].shape[:2]
    sizes = tuple(int(l.shape[-1]) for l in leaves)
    if interpret is None:
        interpret = not _on_tpu()
    if blk is None:
        blk = auto_blk(C, sizes)
    if leaf_scale is None:
        leaf_scale = jnp.ones((len(leaves),), jnp.float32)
    if out_dtypes is None:
        out_dtypes = [jnp.float32] * len(leaves)
    mask = mask.astype(jnp.float32)

    # ---- pass 1: shared accumulators across all leaf segments ----
    dots, sqn, refsq = cosine_gate_partials_leafwise(
        leaves, mask, blk=blk, leaf_scale=leaf_scale, interpret=interpret)
    if axis_name is not None:
        dots = jax.lax.psum(dots, axis_name)
        sqn = jax.lax.psum(sqn, axis_name)
        refsq = jax.lax.psum(refsq, axis_name)

    m = _resolve_gate(dots, sqn, refsq, mask, cosine_thresh)

    combine = functools.partial(gated_combine_leafwise, leaves, m, blk=blk,
                                out_dtypes=out_dtypes, interpret=interpret)
    if aggregator == "fedavg":
        w = weights * m
        w = w / jnp.maximum(w.sum(axis=1, keepdims=True), 1e-12)
        return combine(w, mode="mean")
    if aggregator == "trimmed_mean":
        return combine(m, mode="trimmed", trim_frac=trim_frac)
    if aggregator == "median":
        return combine(m, mode="median")
    if aggregator == "krum":
        d = pairwise_sq_dists_leafwise(
            leaves, m, blk=blk, leaf_scale=leaf_scale, interpret=interpret,
            axis_name=axis_name)
        w = _krum_weights(d, m, krum_f, krum_multi_m)
        return combine(w, mode="mean")
    raise ValueError(aggregator)


# ---------------------------------------------------------------------------
# pytree wrappers (the core/aggregation.py hot path)
# ---------------------------------------------------------------------------

def _flatten_cohorts(updates, lead):
    """Flatten a pytree of (*lead, ...) leaves into one (*lead, N) f32
    matrix; returns (flat, treedef, leaves, sizes).  The PR-1 path — the
    concatenate is an extra (C, N) HBM copy; kept for the ``*_flat``
    baseline/oracle only."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    sizes = [int(l.size // max(1, _prod(l.shape[:lead]))) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(*l.shape[:lead], -1).astype(jnp.float32) for l in leaves],
        axis=-1)
    return flat, treedef, leaves, sizes


def _prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


def _unflatten(agg, treedef, leaves, sizes, lead):
    out, off = [], 0
    for l, n in zip(leaves, sizes):
        out.append(agg[..., off:off + n].reshape(l.shape[lead:]).astype(
            l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def _leaf_views(updates, lead):
    """Reshape-only (no copy) views of the pytree's leaves as a list of
    (*lead, n_l) matrices, in native dtype."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    flat = [l.reshape(*l.shape[:lead], -1) for l in leaves]
    return flat, treedef, leaves


@functools.partial(jax.jit, static_argnames=("cfg", "blk", "interpret"))
def fused_aggregate_tree(updates, weights, mask, cfg, *, blk=None,
                         interpret=None):
    """Single-cohort Eq.-11 aggregation over a pytree of (C, ...) leaves;
    drop-in for ``aggregation.aggregate_ref`` (which stays as the parity
    oracle).  Leaf-streaming: no concatenate, no unflatten copy — each
    leaf is a reshape view into the segment-table passes and is cast back
    to its dtype once, by the pass-2 output write."""
    flat, treedef, leaves = _leaf_views(updates, 1)
    outs = fused_pipeline_leafwise(
        [f[None] for f in flat], weights[None], mask[None],
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk, interpret=interpret,
        out_dtypes=[l.dtype for l in leaves])
    outs = [o[0].reshape(l.shape[1:]) for o, l in zip(outs, leaves)]
    return jax.tree_util.tree_unflatten(treedef, outs)


@functools.partial(jax.jit, static_argnames=("cfg", "blk", "interpret"))
def fused_two_stage_tree(slot_updates, slot_weights, slot_masks, cfg, *,
                         blk=None, interpret=None):
    """Cohort-batched two-stage scheme: every slot rides the G grid axis of
    ONE fused pipeline call per pass (the reference's per-cohort Python
    loop becomes a grid dimension), then the cross-slot size-weighted mean
    in fp32 with one cast per leaf."""
    flat, treedef, leaves = _leaf_views(slot_updates, 2)
    per = fused_pipeline_leafwise(
        flat, slot_weights, slot_masks,
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk, interpret=interpret)                      # [(G, n_l)] f32
    cw = slot_masks.sum(axis=1).astype(jnp.float32)
    cw = cw / jnp.maximum(cw.sum(), 1e-12)
    outs = [jnp.tensordot(cw, p, axes=(0, 0)).reshape(l.shape[2:]).astype(
        l.dtype) for p, l in zip(per, leaves)]
    return jax.tree_util.tree_unflatten(treedef, outs)


@functools.partial(jax.jit, static_argnames=("cfg", "blk", "interpret"))
def fused_aggregate_tree_flat(updates, weights, mask, cfg, *, blk=4096,
                              interpret=None):
    """The PR-1 flatten path (one (C, N) concatenate + unflatten copies).
    Kept as the leafwise bench baseline and a parity oracle."""
    flat, treedef, leaves, sizes = _flatten_cohorts(updates, 1)
    out = fused_pipeline(
        flat[None], weights[None], mask[None],
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk, interpret=interpret)[0]
    return _unflatten(out, treedef, leaves, sizes, 1)


@functools.partial(jax.jit, static_argnames=("cfg", "blk", "interpret"))
def fused_two_stage_tree_flat(slot_updates, slot_weights, slot_masks, cfg,
                              *, blk=4096, interpret=None):
    """PR-1 flatten path of the cohort-batched two-stage scheme (bench
    baseline / parity oracle)."""
    flat, treedef, leaves, sizes = _flatten_cohorts(slot_updates, 2)
    per = fused_pipeline(
        flat, slot_weights, slot_masks,
        aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
        cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
        blk=blk, interpret=interpret)                      # (G, N)
    cw = slot_masks.sum(axis=1).astype(jnp.float32)
    cw = cw / jnp.maximum(cw.sum(), 1e-12)
    combined = jnp.tensordot(cw, per, axes=(0, 0))         # (N,)
    return _unflatten(combined, treedef, leaves, sizes, 2)
