"""Dense reference for paged flash-decode (the parity oracle).

Gathers every slot's pages into a contiguous (S, T, Hkv, dh) K/V block
via the page table, then runs plain fp32 softmax attention — the same
shape of oracle as kernels/flash_attention_ref.py.  The Pallas kernel
(kernels/paged_decode.py) must match this bit-for-bit up to fp32
accumulation order (tests/test_serve.py pins the atol).

Contract shared with the kernel:
  q        (S, Hq, dh)        one query token per slot (GQA: Hq = g*Hkv)
  kp, vp   (N, page, Hkv, dh) page pools (f32, or int8 codes)
  table    (S, maxp) int32    per-slot page table; every entry must be a
                              valid pool index (unallocated entries are 0
                              and masked out by ``lengths``)
  lengths  (S,) int32         visible keys per slot INCLUDING the token
                              appended this step; <= 0 -> zero output
                              (inactive slot)
  k_scale, v_scale (N, page, Hkv) f32  per-(row, head) absmax scales for
                              the int8 pools (comm/codecs.py placement:
                              qblk = dh, one scale per cache row per head)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(pool, table):
    """pool (N, page, ...) gathered to (S, maxp*page, ...) via table."""
    s, maxp = table.shape
    page = pool.shape[1]
    return pool[table].reshape((s, maxp * page) + pool.shape[2:])


def dequant_pool(codes, scale):
    """int8 page pool -> f32: the exact codecs.quant_decode multiply
    (codes * scale), scale broadcast over the dh axis."""
    return codes.astype(jnp.float32) * scale[..., None]


def paged_decode_ref(q, kp, vp, table, lengths, *, k_scale=None,
                     v_scale=None):
    """Returns (S, Hq, dh) f32 attention outputs (see module contract)."""
    s, hq, dh = q.shape
    hkv = kp.shape[2]
    g = hq // hkv
    if k_scale is not None:
        kp = dequant_pool(kp, k_scale)
        vp = dequant_pool(vp, v_scale)
    k = gather_pages(kp, table).astype(jnp.float32)   # (S, T, Hkv, dh)
    v = gather_pages(vp, table).astype(jnp.float32)
    t = k.shape[1]
    qg = q.reshape(s, hkv, g, dh).astype(jnp.float32) * dh ** -0.5
    scores = jnp.einsum("shgd,sthd->shgt", qg, k)
    visible = jnp.arange(t)[None, :] < lengths[:, None]          # (S, T)
    scores = jnp.where(visible[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("shgt,sthd->shgd", probs, v)
    # fully-masked (inactive) slots: all-NEG_INF softmax is uniform
    # garbage — force the contract's zero output
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.reshape(s, hq, dh)
