"""jit'd public wrapper for the flash attention kernel.

Accepts the model's (B, S, H, dh) layout, transposes to the kernel's
(B, H, S, dh), auto-selects interpret mode on CPU, and falls back to the
ref for shapes the kernel can't tile (tiny smoke sizes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention_ref import flash_attention_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, interpret=None):
    """q: (B, S, Hq, dh); k/v: (B, S, Hkv, dh) -> (B, S, Hq, dh)."""
    B, S, Hq, dh = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if S % 128 != 0 or dh % 128 != 0:
        out = flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        if interpret is None:
            interpret = not _on_tpu()
        out = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                                  interpret=interpret)
    return out.transpose(0, 2, 1, 3)
