"""Pure-jnp oracle for the flash attention kernel (same contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Hq, S, dh); k/v: (B, Hkv, S, dh) -> (B, Hq, S, dh)."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, S, dh).astype(jnp.float32) * dh ** -0.5
    scores = jnp.einsum("bhgsd,bhtd->bhgst", qg, k.astype(jnp.float32))
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= cols <= rows
    if window:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bhtd->bhgsd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, S, dh).astype(q.dtype)
