"""Sliding-window causal flash attention — Pallas TPU kernel.

TPU-native design:
  * grid (B, Hq, n_q_blocks, n_kv_blocks); the kv-block axis is minor-most,
    so VMEM scratch (acc, m, l) persists across the kv sweep — the online-
    softmax flash pattern.
  * BlockSpec tiles are (blk_q x head_dim) / (blk_k x head_dim) with
    MXU-aligned 128-multiples; softmax statistics in fp32 on the VPU.
  * GQA folded into the k/v index_map (kv head = q head // group) — no
    materialised head repeat.
  * causal + sliding-window masking fused; kv blocks entirely outside the
    (causal, window) band are skipped via pl.when (the sub-quadratic claim
    for long contexts: compute touches only S*W, not S^2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                blk_q, blk_k, n_k, causal, window, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # block-level skip: kv block entirely above the diagonal (causal) or
    # entirely left of the sliding window of every row in the q block
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + blk_q - 1)
    if window:
        live = jnp.logical_and(live, k_start + blk_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
        mask = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, blk_q=128,
                        blk_k=128, interpret=False):
    """q: (B, Hq, S, dh); k/v: (B, Hkv, S, dh) -> (B, Hq, S, dh)."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    assert S % blk_q == 0 and S % blk_k == 0, (S, blk_q, blk_k)
    n_q, n_k = S // blk_q, S // blk_k
    scale = dh ** -0.5

    kernel = functools.partial(
        _flash_body, blk_q=blk_q, blk_k=blk_k, n_k=n_k, causal=causal,
        window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, dh),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, dh),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, dh),
                         lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, dh),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, dh), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
