"""Pallas flash-decode over a paged KV cache (the serving decode kernel).

One query token per slot attends over that slot's pages, gathered
straight from the (N, page, Hkv, dh) pool into VMEM via the per-slot
page table — no contiguous K/V copy, so eviction never compacts.

Grid ``(S, Hkv, maxp)`` with the page axis minor-most; the page table
and per-slot visible-key counts ride scalar prefetch
(``PrefetchScalarGridSpec``), so the k/v BlockSpec index maps read
``table[s, p]`` to pick which pool page the next block DMA fetches.
Online-softmax accumulators (acc, m, l) live in VMEM scratch and carry
across the page axis exactly like kernels/flash_attention.py carries
across KV blocks; dead pages (``p*page >= lengths[s]``) are skipped with
``pl.when`` (their DMA still lands — table entries for unallocated pages
are 0, a valid pool index — but no FLOPs are spent).

The int8 path fuses dequantization into the page loads: codes are
fetched as int8 (quarter the bytes of f32) and multiplied by the
per-(row, head) f32 scales in VMEM — the exact ``codecs.quant_decode``
multiply, same trick as comm/kernels/comm_codecs.py — so the unquantized
K/V never exist in HBM at all.

Parity oracle: kernels/paged_decode_ref.py (contract documented there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_decode_ref import paged_decode_ref

NEG_INF = -1e30


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, *rest,
            page: int, maxp: int, int8: bool):
    if int8:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    s = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n_keys = len_ref[s]

    @pl.when(p * page < n_keys)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (g, dh)
        q = q * (q.shape[-1] ** -0.5)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (page, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if int8:
            # fused dequant: the exact quant_decode multiply, replayed
            # on the VMEM-resident block (bit-identical to dequantizing
            # in HBM first)
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        scores = jax.lax.dot_general(                # (g, page)
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        kpos = p * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        scores = jnp.where(kpos < n_keys, scores, NEG_INF)
        m_prev = m_ref[...]                          # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(scores - m_new)               # (g, page)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1,
                                                  keepdims=True)
        m_ref[...] = m_new

    @pl.when(p == maxp - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        out = jnp.where(n_keys > 0, out, 0.0)        # inactive slot -> 0
        o_ref[...] = out[None, None].astype(o_ref.dtype)


def paged_flash_decode(q, kp, vp, table, lengths, *, k_scale=None,
                       v_scale=None, interpret=None):
    """Paged flash-decode; same contract as paged_decode_ref.

    Routes to the Pallas kernel (interpret mode off-TPU, like every
    kernel wrapper in this package); falls back to the dense reference
    when the head dim can't tile the TPU lane width.
    """
    s, hq, dh = q.shape
    n, page, hkv, _ = kp.shape
    g = hq // hkv
    maxp = table.shape[1]
    if interpret is None:
        interpret = not _on_tpu()
    if not interpret and dh % 128 != 0:
        return paged_decode_ref(q, kp, vp, table, lengths,
                                k_scale=k_scale, v_scale=v_scale)
    int8 = k_scale is not None

    q4 = q.reshape(s, hkv, g, dh)
    in_specs = [
        pl.BlockSpec((1, 1, g, dh), lambda si, h, p, tab, ln: (si, h, 0, 0)),
        pl.BlockSpec((1, page, 1, dh),
                     lambda si, h, p, tab, ln: (tab[si, p], 0, h, 0)),
        pl.BlockSpec((1, page, 1, dh),
                     lambda si, h, p, tab, ln: (tab[si, p], 0, h, 0)),
    ]
    args = [table, lengths.astype(jnp.int32), q4, kp, vp]
    if int8:
        in_specs += [
            pl.BlockSpec((1, page, 1),
                         lambda si, h, p, tab, ln: (tab[si, p], 0, h)),
            pl.BlockSpec((1, page, 1),
                         lambda si, h, p, tab, ln: (tab[si, p], 0, h)),
        ]
        args += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(s, hkv, maxp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda si, h, p, tab, ln: (si, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, dh), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, page=page, maxp=maxp, int8=int8),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, hkv, g, dh), jnp.float32),
        interpret=interpret,
    )(*args)
    return out.reshape(s, hq, dh)
