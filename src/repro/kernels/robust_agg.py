"""Masked coordinate-robust client aggregation — Pallas TPU kernel.

The paper's robust-fallback hot path (trimmed-mean / median over the
client axis, Eq. 11) as a TPU kernel:

  * input is the (C, N) matrix of flattened client updates (C = clients,
    N = parameters); grid streams N in VMEM-sized blocks, C stays resident.
  * instead of a sort (host-style) the kernel computes per-coordinate
    *ranks* with an O(C^2) compare network — C <= 64, so C^2 elementwise
    VPU ops per block beat a data-dependent sort on the TPU vector unit,
    and everything stays in registers/VMEM.
  * masked-out clients get rank >= C (pushed past every real row) so the
    same network serves any team mask; n_selected arrives as an SMEM
    scalar.
  * modes: trimmed mean (drop floor(trim*n) per side) and median
    (average of the middle one/two ranks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 1e30


def stable_ranks(xm, c):
    """Per-coordinate stable ranks of an already-masked (C, blk) block:
    rank_i = #{j: x_j < x_i} + #{j<i: x_j == x_i}. Masked-out rows must
    arrive pushed to +_BIG so they rank past every real row. O(C^2)
    elementwise VPU ops — for C <= 64 this beats a data-dependent sort on
    the TPU vector unit and keeps everything in registers/VMEM. Shared by
    robust_agg and the fused robust_pipeline kernels."""
    xi = xm[:, None, :]                           # (C, 1, blk)
    xj = xm[None, :, :]                           # (1, C, blk)
    less = (xj < xi).astype(jnp.float32)
    row_i = jax.lax.broadcasted_iota(jnp.int32, (c, c, 1), 0)
    row_j = jax.lax.broadcasted_iota(jnp.int32, (c, c, 1), 1)
    tie = ((xj == xi) & (row_j < row_i)).astype(jnp.float32)
    return (less + tie).sum(axis=1)               # (C, blk)


def _robust_body(n_ref, x_ref, mask_ref, o_ref, *, c, blk, mode, trim_frac):
    x = x_ref[...].astype(jnp.float32)            # (C, blk)
    m = mask_ref[...].astype(jnp.float32)         # (C, 1)
    n = n_ref[0].astype(jnp.float32)              # selected count

    xm = jnp.where(m > 0, x, _BIG)                # masked rows past everyone
    rank = stable_ranks(xm, c)                    # (C, blk)

    if mode == "trimmed":
        t = jnp.floor(trim_frac * n)
        keep = ((rank >= t) & (rank < n - t)).astype(jnp.float32) * m
        cnt = jnp.maximum(n - 2.0 * t, 1.0)
        o_ref[...] = ((x * keep).sum(axis=0, keepdims=True) / cnt
                      ).astype(o_ref.dtype)
    else:                                          # median
        lo = jnp.floor((n - 1.0) / 2.0)
        hi = jnp.ceil((n - 1.0) / 2.0)
        pick_lo = (rank == lo).astype(jnp.float32) * m
        pick_hi = (rank == hi).astype(jnp.float32) * m
        med = 0.5 * ((x * pick_lo).sum(axis=0, keepdims=True)
                     + (x * pick_hi).sum(axis=0, keepdims=True))
        o_ref[...] = med.astype(o_ref.dtype)


def robust_agg_fwd(x, mask, *, mode="trimmed", trim_frac=0.2, blk=2048,
                   interpret=False):
    """x: (C, N) f32; mask: (C,) 0/1 -> (N,) aggregated coordinates."""
    C, N = x.shape
    blk = min(blk, N)
    assert N % blk == 0, (N, blk)
    n_sel = jnp.asarray([mask.sum()], jnp.float32)

    kernel = functools.partial(_robust_body, c=C, blk=blk, mode=mode,
                               trim_frac=trim_frac)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N // blk,),
        in_specs=[
            pl.BlockSpec((C, blk), lambda i, n: (0, i)),
            pl.BlockSpec((C, 1), lambda i, n: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i, n: (0, i)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, N), x.dtype),
        interpret=interpret,
    )(n_sel, x, mask.reshape(C, 1))
    return out[0]
