"""Pure-jnp oracle for the robust aggregation kernel — delegates to the
core aggregators (single source of truth for the contract)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import aggregation


def robust_agg_ref(x, mask, *, mode="trimmed", trim_frac=0.2):
    """x: (C, N) f32; mask: (C,) -> (N,)."""
    if mode == "trimmed":
        return aggregation.trimmed_mean(x, mask, trim_frac)
    return aggregation.median(x, mask)
