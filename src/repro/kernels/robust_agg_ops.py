"""jit'd public wrapper: robust aggregation over *pytrees* of client
updates. Flattens every (C, ...) leaf into one (C, N) matrix, pads N to
the kernel block, runs the Pallas kernel, and unflattens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.robust_agg import robust_agg_fwd
from repro.kernels.robust_agg_ref import robust_agg_ref


def _on_tpu():
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("mode", "trim_frac", "blk", "interpret"))
def robust_aggregate_tree(updates, mask, *, mode="trimmed", trim_frac=0.2,
                          blk=2048, interpret=None):
    """updates: pytree of (C, ...) leaves; mask: (C,) -> pytree of (...)."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    C = leaves[0].shape[0]
    sizes = [int(l.size // C) for l in leaves]
    flat = jnp.concatenate(
        [l.reshape(C, -1).astype(jnp.float32) for l in leaves], axis=1)
    N = flat.shape[1]
    blk = min(blk, max(128, N))
    pad = (-N) % blk
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    if interpret is None:
        interpret = not _on_tpu()
    agg = robust_agg_fwd(flat, mask.astype(jnp.float32), mode=mode,
                         trim_frac=trim_frac, blk=blk, interpret=interpret)
    agg = agg[:N]
    out, off = [], 0
    for l, n in zip(leaves, sizes):
        out.append(agg[off:off + n].reshape(l.shape[1:]).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def robust_aggregate_tree_ref(updates, mask, *, mode="trimmed",
                              trim_frac=0.2):
    """Oracle with the same pytree contract."""
    if mode == "trimmed":
        from repro.core.aggregation import trimmed_mean
        return trimmed_mean(updates, mask, trim_frac)
    from repro.core.aggregation import median
    return median(updates, mask)
