"""Schema check for emitted telemetry artifacts (CI's telemetry smoke
gate, also used by tests/test_obs.py).

  PYTHONPATH=src python -m repro.obs.check \
      --trace out.json --jsonl metrics.jsonl [--min-phases 5] \
      [--require-obs] [--engine async]

Validates that

  * the trace file is Chrome/Perfetto-loadable trace-event JSON (a
    ``traceEvents`` list of complete "X" events with name/ts/dur), and
    that every round on the round track carries at least
    ``--min-phases`` DISTINCT phase spans (the acceptance bar is 5);
  * the JSONL stream is one JSON object per line with a known ``kind``
    (metrics | warning | summary), metrics rows carry a round/step
    index, and — with ``--require-obs`` — the registered counters of
    ``--engine`` are all present on every metrics row.

Exit code 0 = clean; 1 = findings (printed one per line).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.obs import counters as obs_counters
from repro.obs.trace import PHASE_NAMES

KINDS = {"metrics", "warning", "summary"}


def check_trace(trace, *, min_phases: int = 5) -> List[str]:
    """Validate a trace-event dict (or path); returns finding strings."""
    errs: List[str] = []
    if isinstance(trace, str):
        try:
            with open(trace) as f:
                trace = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [f"trace: unreadable ({e})"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["trace: no traceEvents list"]
    per_round: dict = {}
    measured_rounds: set = set()
    for i, e in enumerate(evs):
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in e:
                errs.append(f"trace: event {i} missing {field!r}")
                break
        else:
            if e["ph"] == "X" and ("dur" not in e or e["dur"] <= 0):
                errs.append(
                    f"trace: event {i} ({e['name']}) X-phase without "
                    "positive dur")
            args = e.get("args", {})
            if e["name"] in PHASE_NAMES and "round" in args:
                per_round.setdefault(args["round"], set()).add(e["name"])
            elif e["name"] == "round" and "round" in args:
                # measured per-round span (python driver / serving
                # engine) — counts as round coverage without a phase
                # split
                measured_rounds.add(args["round"])
    if not per_round and not measured_rounds:
        errs.append("trace: no per-round spans (expected phase names "
                    f"from {list(PHASE_NAMES)} or measured 'round' "
                    "spans)")
    for rnd, names in sorted(per_round.items()):
        if len(names) < min_phases:
            errs.append(
                f"trace: round {rnd} has {len(names)} distinct phase "
                f"spans ({sorted(names)}), need >= {min_phases}")
    return errs


def check_jsonl(path: str, *, require_obs: bool = False,
                engine: Optional[str] = None) -> List[str]:
    """Validate a telemetry JSONL stream; returns finding strings."""
    errs: List[str] = []
    want = None
    if require_obs:
        want = {obs_counters.METRIC_PREFIX + n
                for n in obs_counters.specs_for(engine or "sync")}
    n_metrics = n_summary = 0
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        return [f"jsonl: unreadable ({e})"]
    for i, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errs.append(f"jsonl:{i}: not JSON ({e})")
            continue
        kind = rec.get("kind")
        if kind not in KINDS:
            errs.append(f"jsonl:{i}: unknown kind {kind!r}")
            continue
        if kind == "metrics":
            n_metrics += 1
            if "round" not in rec and "step" not in rec:
                errs.append(f"jsonl:{i}: metrics row without round/step")
            if want is not None:
                missing = want - set(rec)
                if missing:
                    errs.append(f"jsonl:{i}: metrics row missing "
                                f"{sorted(missing)[:3]}"
                                f"{'...' if len(missing) > 3 else ''}")
        elif kind == "warning":
            for field in ("monitor", "value", "threshold"):
                if field not in rec:
                    errs.append(f"jsonl:{i}: warning without {field!r}")
        else:
            n_summary += 1
    if n_metrics == 0:
        errs.append("jsonl: no metrics records")
    if n_summary == 0:
        errs.append("jsonl: no summary record (run not finished?)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.check",
        description="Schema-check telemetry trace/JSONL artifacts")
    ap.add_argument("--trace", default=None)
    ap.add_argument("--jsonl", default=None)
    ap.add_argument("--min-phases", type=int, default=5)
    ap.add_argument("--require-obs", action="store_true",
                    help="metrics rows must carry every registered "
                         "counter of --engine")
    ap.add_argument("--engine", default="sync",
                    choices=["sync", "async", "serve"])
    args = ap.parse_args(argv)
    if not (args.trace or args.jsonl):
        ap.error("nothing to check: pass --trace and/or --jsonl")
    errs: List[str] = []
    if args.trace:
        errs += check_trace(args.trace, min_phases=args.min_phases)
    if args.jsonl:
        errs += check_jsonl(args.jsonl, require_obs=args.require_obs,
                            engine=args.engine)
    for e in errs:
        print(e)
    if not errs:
        checked = [p for p in (args.trace, args.jsonl) if p]
        print(f"ok: {', '.join(checked)}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
