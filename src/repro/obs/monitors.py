"""Drift monitors: threshold tripwires over the drained metric stream.

A :class:`Monitor` watches one derived per-round value and fires after
the predicate holds for ``k_consecutive`` rounds — the "gate rejected
>50% of the cohort for 3 straight rounds" class of silent degradation
the end-of-run summary can't surface.  Warnings are structured records
(``kind="warning"``) emitted into the same sink stream as the metrics,
so a JSONL tail or the scenario summary sees them in order.

Monitors run host-side on already-drained rows: they cannot perturb the
run, and they see exactly what the engine measured.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Callable, Dict, List, Optional, Sequence

OPS = {">": operator.gt, ">=": operator.ge,
       "<": operator.lt, "<=": operator.le}


@dataclasses.dataclass
class Monitor:
    """Fire when ``value(row) op threshold`` holds k rounds running."""
    name: str
    value: Callable[[dict], Optional[float]]   # None = not applicable
    op: str
    threshold: float
    k_consecutive: int = 1
    doc: str = ""
    # internal streak state
    _streak: int = dataclasses.field(default=0, init=False)
    _fired: int = dataclasses.field(default=0, init=False)

    def observe(self, row: dict) -> Optional[dict]:
        v = self.value(row)
        if v is None:
            return None
        v = float(v)
        if OPS[self.op](v, self.threshold):
            self._streak += 1
        else:
            self._streak = 0
            return None
        if self._streak < self.k_consecutive:
            return None
        self._fired += 1
        return {
            "kind": "warning", "monitor": self.name,
            "round": _round_of(row), "value": v,
            "threshold": self.threshold, "op": self.op,
            "streak": self._streak, "doc": self.doc,
        }


def _round_of(row: dict):
    for k in ("round", "step"):
        if k in row:
            try:
                return int(float(row[k]))
            except (TypeError, ValueError):
                return row[k]
    return None


def _obs(row: dict, name: str) -> Optional[float]:
    v = row.get("obs/" + name)
    return None if v is None else float(v)


def _ratio(num: Optional[float], den: Optional[float]) -> Optional[float]:
    if num is None or den is None or den <= 0:
        return None
    return num / den


def _gate_frac(row):
    return _ratio(_obs(row, "gate/cosine_rejected"),
                  _obs(row, "select/team_size"))


def _guard_frac(row):
    g = [_obs(row, "guard/nonfinite"), _obs(row, "guard/norm")]
    if any(x is None for x in g):
        return None
    return _ratio(sum(g), _obs(row, "select/team_size"))


def _overflow_frac(row):
    o = _obs(row, "buffer/overflow")
    p = _obs(row, "buffer/parked")
    if o is None or p is None:
        return None
    return _ratio(o, o + p) if (o + p) > 0 else 0.0


def _trust_p50(row):
    q = row.get("obs/cohort/trust_q")
    if q is None:
        return None
    try:
        return float(q[1])
    except (TypeError, IndexError):
        return None


def default_monitors() -> List[Monitor]:
    """The stock tripwires; callers extend or replace freely."""
    return [
        Monitor("gate_rejecting_majority", _gate_frac, ">", 0.5,
                k_consecutive=3,
                doc="cosine gate rejected >50% of the cohort for 3 "
                    "consecutive rounds — model drift or gate "
                    "miscalibration"),
        Monitor("guard_rejecting_majority", _guard_frac, ">", 0.5,
                k_consecutive=2,
                doc="sanitize boundary rejected >50% of deliveries for "
                    "2 consecutive rounds — poisoning or numeric "
                    "blow-up upstream"),
        Monitor("buffer_overflowing", _overflow_frac, ">", 0.25,
                k_consecutive=2,
                doc=">25% of late deliveries dropped for lack of buffer "
                    "slots — raise async_max_retries or the deadline"),
        Monitor("cohort_trust_collapsed", _trust_p50, "<", 0.1,
                k_consecutive=3,
                doc="median cohort trust under 0.1 for 3 consecutive "
                    "rounds — the scheduler is starving"),
    ]


class MonitorBank:
    """Runs a monitor set over each drained row, collecting warnings."""

    def __init__(self, monitors: Optional[Sequence[Monitor]] = None):
        self.monitors = list(monitors if monitors is not None
                             else default_monitors())
        self.warnings: List[dict] = []

    def observe(self, row: dict) -> List[dict]:
        fired = []
        for m in self.monitors:
            w = m.observe(row)
            if w is not None:
                fired.append(w)
        self.warnings.extend(fired)
        return fired

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for w in self.warnings:
            out[w["monitor"]] = out.get(w["monitor"], 0) + 1
        return out
