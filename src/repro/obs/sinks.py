"""Telemetry sinks: where drained metric rows and monitor warnings go.

A sink consumes already-host-side records — the driver has drained the
chunk, rows are numpy scalars — so sinks never touch device state and
can't perturb the run.  Protocol: ``emit(record)``, ``flush()``,
``close()``.  Implementations:

  * :class:`JsonlSink` — one JSON object per line, the machine-readable
    stream CI schema-checks (obs/check.py).
  * :class:`MemorySink` — bounded in-memory ring for tests and the
    scenario engine (every matrix cell keeps its telemetry record
    without touching disk).
  * :class:`StdoutSink` — prefixed human-readable lines.
  * :class:`MultiSink` — fan-out.
"""
from __future__ import annotations

import collections
import json
import sys
from typing import IO, Iterable, List, Optional

import numpy as np


def jsonable(v):
    """Coerce numpy/JAX scalars and arrays into JSON-native values."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    if isinstance(v, dict):
        return {k: jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    arr = np.asarray(v)
    if arr.ndim == 0:
        f = float(arr)
        return int(f) if float(f).is_integer() and abs(f) < 2**53 else f
    return [jsonable(x) for x in arr.tolist()]


class Sink:
    """Base sink: subclass and override ``emit``."""

    def emit(self, record: dict) -> None:  # pragma: no cover - interface
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()


class JsonlSink(Sink):
    def __init__(self, path: str):
        self.path = path
        self._f: Optional[IO[str]] = open(path, "w")

    def emit(self, record: dict) -> None:
        if self._f is None:
            raise ValueError(f"JsonlSink({self.path}) already closed")
        self._f.write(json.dumps(jsonable(record)) + "\n")

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class MemorySink(Sink):
    def __init__(self, capacity: int = 4096):
        self.records: collections.deque = collections.deque(maxlen=capacity)

    def emit(self, record: dict) -> None:
        self.records.append(jsonable(record))

    def by_kind(self, kind: str) -> List[dict]:
        return [r for r in self.records if r.get("kind") == kind]


class StdoutSink(Sink):
    def __init__(self, stream: Optional[IO[str]] = None, prefix: str = "# obs "):
        self.stream = stream or sys.stdout
        self.prefix = prefix

    def emit(self, record: dict) -> None:
        self.stream.write(self.prefix + json.dumps(jsonable(record)) + "\n")

    def flush(self) -> None:
        self.stream.flush()


class MultiSink(Sink):
    def __init__(self, sinks: Iterable[Sink]):
        self.sinks = list(sinks)

    def emit(self, record: dict) -> None:
        for s in self.sinks:
            s.emit(record)

    def flush(self) -> None:
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        for s in self.sinks:
            s.close()
