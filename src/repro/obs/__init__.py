"""Round-trace telemetry (obs layer): counters, traces, monitors, sinks.

:class:`Telemetry` is the one object callers hand to ``fedfits.run`` /
``async_engine.run_async`` / ``pod.run`` / ``run_scenario``.  It owns

  * the **counter registry** switch (``counters=True``): the round
    bodies publish the registered on-device signals as an extra carry
    column + ``obs/`` metric keys (see obs/counters.py) — a pure
    readout, bit-parity preserving;
  * the **trace recorder** (``trace_path=...``): Perfetto trace-event
    JSON with measured driver spans and attributed per-round phase
    spans (see obs/trace.py), plus the ``profiler_dir`` escape hatch
    wrapping the run in ``jax.profiler.trace``;
  * the **sink stream + drift monitors**: every drained row becomes a
    ``kind="metrics"`` record, every monitor trip a ``kind="warning"``
    record, fanned to the configured sinks (see obs/sinks.py,
    obs/monitors.py).

Everything runs host-side at the existing ``on_chunk`` drain boundary —
telemetry adds zero host syncs and zero device ops that feed the model.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

from repro.obs import counters, monitors as monitors_mod, sinks as sinks_mod
from repro.obs.counters import METRIC_PREFIX
from repro.obs.monitors import Monitor, MonitorBank, default_monitors
from repro.obs.sinks import (JsonlSink, MemorySink, MultiSink, Sink,
                             StdoutSink, jsonable)
from repro.obs.trace import (PHASE_NAMES, TraceRecorder, annotate,
                             phase_weights, profiler_session)

__all__ = [
    "Telemetry", "Monitor", "MonitorBank", "default_monitors",
    "Sink", "JsonlSink", "MemorySink", "MultiSink", "StdoutSink",
    "TraceRecorder", "annotate", "profiler_session", "jsonable",
    "PHASE_NAMES", "METRIC_PREFIX", "counters",
]


class Telemetry:
    """Facade wiring counters, traces, sinks and monitors together.

    Construct once per run; the engines route it to the driver and the
    metric drain.  ``engine`` is set by whichever run() consumes it.
    """

    def __init__(self, *,
                 counters: bool = True,
                 sinks: Optional[Sequence[Sink]] = None,
                 monitors: Optional[Sequence[Monitor]] = None,
                 trace_path: Optional[str] = None,
                 profiler_dir: Optional[str] = None,
                 run_name: str = "run"):
        self.counters = counters
        self.sink: Sink = MultiSink(sinks or [])
        self.bank = MonitorBank(monitors)
        self.trace_path = trace_path
        self.profiler_dir = profiler_dir
        self.run_name = run_name
        self.engine: str = "sync"
        self.tracer: Optional[TraceRecorder] = (
            TraceRecorder() if trace_path else None)
        self.rows_seen = 0
        self._finished = False

    # -- engine hooks --------------------------------------------------
    def bind_engine(self, engine: str) -> "Telemetry":
        """Called by the consuming run(): fixes the engine's phase
        weights and counter slice."""
        self.engine = engine
        if self.tracer is not None:
            self.tracer.engine = engine
            self.tracer._weights = phase_weights(engine)
        return self

    def observe_rows(self, rows: Sequence[dict],
                     window_start_us: Optional[float] = None,
                     window_dur_us: Optional[float] = None, *,
                     measured: bool = False,
                     phases: bool = True) -> None:
        """Drain boundary: one call per chunk (scan) or round (python).
        Emits metrics records, runs monitors, and — when tracing —
        attributes the measured window across rounds and phases.
        ``measured=True`` marks the window as one real host measurement
        per row (python driver, serving engine): each round gets a
        measured ``round`` span; ``phases=False`` skips the attributed
        phase split (see TraceRecorder.emit_rounds)."""
        rows = list(rows)
        if not rows:
            return
        for row in rows:
            self.rows_seen += 1
            rec = {"kind": "metrics", "engine": self.engine,
                   "run": self.run_name}
            rec.update(jsonable(row))
            self.sink.emit(rec)
            for w in self.bank.observe(row):
                w = dict(w)
                w["engine"] = self.engine
                w["run"] = self.run_name
                self.sink.emit(w)
        if self.tracer is not None:
            if window_dur_us is None:
                # no measured window handed in (python driver emits per
                # round); synthesize a zero-cost marker window
                window_start_us = self.tracer.now_us()
                window_dur_us = float(len(rows))
            self.tracer.emit_rounds(window_start_us, window_dur_us, rows,
                                    measured=measured, phases=phases)

    # driver-measured spans pass straight through to the recorder
    def begin(self, name: str) -> None:
        if self.tracer is not None:
            self.tracer.begin(name)

    def end(self, name: str, **args) -> None:
        if self.tracer is not None:
            self.tracer.end(name, **args)

    def now_us(self) -> float:
        return self.tracer.now_us() if self.tracer is not None else \
            time.perf_counter() * 1e6

    # -- lifecycle -----------------------------------------------------
    def profiled(self):
        """Context manager for the jax.profiler escape hatch."""
        return profiler_session(self.profiler_dir)

    def summary(self) -> dict:
        return {"kind": "summary", "engine": self.engine,
                "run": self.run_name, "rows": self.rows_seen,
                "warnings": self.bank.counts(),
                "n_warnings": len(self.bank.warnings)}

    def finish(self) -> dict:
        """Flush sinks, write the trace file; idempotent."""
        s = self.summary()
        if self._finished:
            return s
        self._finished = True
        self.sink.emit(s)
        if self.tracer is not None and self.trace_path:
            self.tracer.save(self.trace_path)
        self.sink.close()
        return s
