"""Phase-level trace emitter: Chrome/Perfetto trace-event JSON.

Rounds run *inside* one jitted ``lax.scan`` chunk, so the host cannot
clock individual phases without breaking the 1-host-sync-per-chunk
contract.  The emitter is therefore two-tier and honest about which
tier is which:

  * **measured spans** — the ScanDriver (and the python-driver loops)
    wall-clock what the host can actually see: per-chunk ``stage`` /
    ``compute`` / ``drain`` spans, and — under the python driver and
    the serving engine, which both sync once per round/step — a
    measured per-round ``round`` span (``emit_rounds(measured=True)``;
    no ``attributed`` flag, the boundaries are real ``perf_counter``
    timestamps).
  * **attributed spans** — inside a chunk, each round's window is split
    into the engine's phase sequence (selection → client_update →
    delivery → sanitize → aggregate → writeback) by the static weight
    tables below.  The span BOUNDARIES are attribution, not
    measurement — ``args.attributed`` marks them — but each span's
    ``args`` carry that round's REAL drained counter values
    (``obs/...`` metrics), so the trace still answers "what did the
    gate/buffer/aggregator do in round t".

For ground-truth device timings use the escape hatch: pass
``profiler_dir`` to :class:`Telemetry` (``--profile-dir`` on the
launcher) and the whole run is wrapped in ``jax.profiler.trace`` —
XLA-level timelines, at XLA-level volume.

Inside jit, :func:`annotate` stacks ``jax.named_scope`` (names the ops
in jaxprs/HLO, so profiler traces and the analysis linter see phase
names) with ``jax.profiler.TraceAnnotation`` when a profiler is active.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax

# The canonical phase sequence (name, sync weight, async weight).
# Weights are the static attribution split of a round's window; they
# are documentation-grade estimates (client_update dominates: it is the
# vmapped local-epochs loop), not measurements — see module docstring.
PHASES: Tuple[Tuple[str, float, float], ...] = (
    ("selection", 0.05, 0.08),
    ("client_update", 0.60, 0.52),
    ("delivery", 0.05, 0.12),
    ("sanitize", 0.05, 0.05),
    ("aggregate", 0.15, 0.13),
    ("writeback", 0.10, 0.10),
)

PHASE_NAMES: Tuple[str, ...] = tuple(p[0] for p in PHASES)


def phase_weights(engine: str) -> Dict[str, float]:
    col = 1 if engine == "sync" else 2
    w = {p[0]: p[col] for p in PHASES}
    total = sum(w.values())
    return {k: v / total for k, v in w.items()}


def counter_tracks() -> Tuple[str, ...]:
    """The registered scalar gauges exported as Perfetto counter ("C")
    tracks: the async buffer occupancy plus every serve/* gauge."""
    from repro.obs import counters as obs_counters
    return tuple(
        n for n, s in obs_counters.REGISTRY.items()
        if s.kind == obs_counters.KIND_GAUGE and s.shape == ()
        and (n == "buffer/occupancy" or n.startswith("serve/")))


@contextlib.contextmanager
def annotate(name: str):
    """Phase annotation inside jitted round bodies: names the ops for
    jaxpr/HLO/profiler consumers.  Pure metadata — no ops are added, so
    telemetry-on stays bit-identical."""
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


class TraceRecorder:
    """Collects trace events and writes ``{"traceEvents": [...]}``.

    Events use the Chrome trace-event "X" (complete) phase with
    microsecond timestamps; ``pid`` groups engines, ``tid`` separates
    the driver track (0) from the round track (1).
    """

    DRIVER_TID = 0
    ROUND_TID = 1

    def __init__(self, engine: str = "sync"):
        self.engine = engine
        self.events: List[dict] = []
        self._t0 = time.perf_counter()
        self._weights = phase_weights(engine)
        self._open: Dict[str, float] = {}

    # -- measured spans (host wall clock) -----------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def begin(self, name: str) -> None:
        self._open[name] = self.now_us()

    def end(self, name: str, **args) -> None:
        start = self._open.pop(name, None)
        if start is None:
            return
        self.span(name, start, self.now_us() - start,
                  tid=self.DRIVER_TID, **args)

    def span(self, name: str, ts_us: float, dur_us: float, *,
             tid: int = 0, **args) -> None:
        self.events.append({
            "name": name, "ph": "X", "pid": 0, "tid": tid,
            "ts": ts_us, "dur": max(dur_us, 0.01),
            "args": args,
        })

    # -- per-round spans (measured and/or attributed) -----------------
    def emit_rounds(self, window_start_us: float, window_dur_us: float,
                    rows: Sequence[dict], *, measured: bool = False,
                    phases: bool = True) -> None:
        """Split a measured window (one chunk, or one python-driver
        round) across its rounds and each round across the engine's
        phases.  ``rows`` are the drained history rows; each phase span
        carries the round's real ``obs/`` counters in ``args``.

        measured=True: the window IS one real host measurement per row
        (python driver, serving engine), so each round additionally
        gets a measured ``round`` span — real timestamps, no
        ``attributed`` flag.  phases=False drops the attributed phase
        split entirely (the serving engine has no FL phase sequence).
        Scalar gauges from :func:`counter_tracks` are always exported
        as Perfetto counter ("C") events at each round's start."""
        if not rows:
            return
        tracks = counter_tracks()
        per_round = window_dur_us / len(rows)
        for j, row in enumerate(rows):
            r0 = window_start_us + j * per_round
            rnd = row.get("round", row.get("step", j))
            obs = {k: _num(v) for k, v in row.items()
                   if isinstance(k, str) and k.startswith("obs/")}
            if measured:
                self.span("round", r0, per_round, tid=self.ROUND_TID,
                          round=_num(rnd), **obs)
            for name in tracks:
                v = obs.get("obs/" + name)
                if isinstance(v, (int, float)):
                    self.events.append({
                        "name": name, "ph": "C", "pid": 0,
                        "tid": self.ROUND_TID, "ts": r0,
                        "args": {"value": v}})
            if not phases:
                continue
            off = 0.0
            for name in PHASE_NAMES:
                dur = per_round * self._weights[name]
                self.span(name, r0 + off, dur, tid=self.ROUND_TID,
                          round=_num(rnd), attributed=True, **obs)
                off += dur

    def to_json(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"engine": self.engine,
                              "phase_weights": self._weights}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path


def _num(v):
    try:
        f = float(v)
    except (TypeError, ValueError):
        return str(v)
    return int(f) if f == int(f) else f


@contextlib.contextmanager
def profiler_session(profiler_dir: Optional[str]):
    """The ground-truth escape hatch: wrap the run in
    ``jax.profiler.trace`` when a directory is given, else no-op."""
    if profiler_dir:
        with jax.profiler.trace(profiler_dir):
            yield
    else:
        yield
