"""On-device counter/metric registry (ROADMAP item 7, the obs layer).

The round engines used to expose their outcome signals (gate rejections,
buffer occupancy, billed bytes) as ad-hoc entries scattered through the
per-round metrics dict.  This module makes them a REGISTRY: every
telemetry signal is declared once as a :class:`CounterSpec` — a typed,
named, documented on-device array — and the engines publish them through
two channels that both respect the driver invariants:

  * **carry column** — cumulative counters ride the scan carry as ONE
    pytree column (``FedState.tele`` / ``AsyncState.tele``, a flat
    ``{name: jnp.ndarray}`` dict built by :func:`init_column`), updated
    with :func:`accumulate` each round.  Totals survive chunk
    boundaries, donation, and checkpointing exactly like every other
    carry field.
  * **per-round metrics** — the same round's instantaneous values are
    folded into the metrics dict under ``obs/<name>`` keys
    (:func:`metric_keys`), so they stack through ``lax.scan`` and drain
    through the existing ``on_chunk`` boundary — the 1-host-sync-per-
    chunk contract is untouched.

Telemetry is a PURE READOUT: every counter is computed from values the
round already produces (masks, weights, the delivery buffer) and nothing
downstream reads it back, so model state, rng streams and billing are
bit-identical with telemetry on or off (tests/test_obs.py asserts this
for both engines under both drivers).

Counter-naming scheme (``<subsystem>/<signal>``):

  gate/…       cosine-gate outcomes            (gate/cosine_rejected)
  guard/…      sanitize-boundary rejections by kind
               (guard/nonfinite, guard/norm)
  buffer/…     async DeliveryBuffer occupancy/parked/overflow/exhausted
               and the retry-age histogram (buffer/age_hist)
  delivery/…   on-time vs late arrival counts
  agg/…        aggregation-weight mass split fresh vs stale
  cohort/…     per-cohort trust/fitness/gate-trust quantiles
               ([p10, p50, p90] gauges)
  select/…     cohort/team size and availability
  wire/…       MEASURED uplink/downlink bytes (mirrors cost_bytes_*)
  fault/…      injected-fault outcomes (mid-round losses)

  serve/…      serving-engine signals (slot occupancy, admits/evicts,
               pages in use, decode throughput) — per decode STEP

The privacy accountant (ROADMAP item 2) will publish its per-round ε
spend as ``privacy/epsilon`` through exactly this registry.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

METRIC_PREFIX = "obs/"

KIND_COUNTER = "counter"      # monotonic; carry column accumulates
KIND_GAUGE = "gauge"          # instantaneous; carry column holds last


@dataclasses.dataclass(frozen=True)
class CounterSpec:
    """One registered telemetry signal."""
    name: str                           # "<subsystem>/<signal>"
    kind: str                           # counter | gauge
    doc: str
    engines: Tuple[str, ...] = ("sync", "async")
    shape: Tuple[int, ...] = ()         # () scalar; histograms/quantiles
                                        # declare their static length via
                                        # shape_for (cfg-dependent)
    unit: str = "count"


REGISTRY: Dict[str, CounterSpec] = {}


def register(spec: CounterSpec) -> CounterSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"duplicate counter {spec.name!r}")
    REGISTRY[spec.name] = spec
    return spec


def _r(name, kind, doc, engines=("sync", "async"), unit="count"):
    return register(CounterSpec(name, kind, doc, tuple(engines), (), unit))


# quantile gauges are fixed [p10, p50, p90] vectors
QUANTILE_PROBS = (0.1, 0.5, 0.9)

# ---- gate / guard ----------------------------------------------------
_r("gate/cosine_rejected", KIND_COUNTER,
   "participants whose update fell under the cosine-gate threshold")
_r("guard/nonfinite", KIND_COUNTER,
   "deliveries rejected by the sanitize boundary for NaN/Inf")
_r("guard/norm", KIND_COUNTER,
   "deliveries rejected for an absurd norm (> mult x masked median)")
# ---- selection / delivery -------------------------------------------
_r("select/team_size", KIND_GAUGE, "cohort/team rows this round")
_r("select/available", KIND_GAUGE, "available clients this round",
   engines=("sync",))
_r("delivery/on_time", KIND_COUNTER,
   "cohort deliveries that beat the round deadline", engines=("async",))
_r("delivery/late", KIND_COUNTER,
   "cohort deliveries that missed the deadline", engines=("async",))
# ---- async buffer ----------------------------------------------------
_r("buffer/occupancy", KIND_GAUGE,
   "DeliveryBuffer rows active after this round's update",
   engines=("async",), unit="rows")
_r("buffer/parked", KIND_COUNTER,
   "late deliveries parked into the buffer this round",
   engines=("async",))
_r("buffer/overflow", KIND_COUNTER,
   "late deliveries dropped because the buffer was full",
   engines=("async",))
_r("buffer/exhausted", KIND_COUNTER,
   "buffered rows abandoned after their retry budget ran out",
   engines=("async",))
register(CounterSpec(
    "buffer/age_hist", KIND_GAUGE,
    "active buffered rows by retry age (bucket i = age i+1)",
    ("async",), (), "rows"))
# ---- aggregation mass ------------------------------------------------
_r("agg/fresh_mass", KIND_GAUGE,
   "aggregation-weight mass of on-time deliveries", unit="mass")
_r("agg/stale_mass", KIND_GAUGE,
   "aggregation-weight mass of stale/buffered catch-up deliveries",
   unit="mass")
# ---- cohort state quantiles -----------------------------------------
register(CounterSpec("cohort/trust_q", KIND_GAUGE,
                     "cohort trust [p10, p50, p90]",
                     ("sync", "async"), (3,), "trust"))
register(CounterSpec("cohort/gate_trust_q", KIND_GAUGE,
                     "cohort gate-trust EWMA [p10, p50, p90]",
                     ("sync", "async"), (3,), "trust"))
register(CounterSpec("cohort/fitness_q", KIND_GAUGE,
                     "cohort fitness score [p10, p50, p90]",
                     ("sync", "async"), (3,), "score"))
# ---- measured wire bytes --------------------------------------------
_r("wire/bytes_up", KIND_COUNTER,
   "measured uplink bytes billed this round", unit="bytes")
_r("wire/bytes_down", KIND_COUNTER,
   "measured downlink bytes billed this round", unit="bytes")
# ---- fault injection -------------------------------------------------
_r("fault/lost", KIND_COUNTER,
   "selected clients whose update was lost mid-round",
   engines=("sync",))
# ---- serving (ROADMAP item 3; rows are per decode STEP, not round) ---
_r("serve/admitted", KIND_COUNTER,
   "requests admitted into decode slots this step", engines=("serve",),
   unit="requests")
_r("serve/evicted", KIND_COUNTER,
   "requests evicted (EOS / length budget) this step",
   engines=("serve",), unit="requests")
_r("serve/tokens", KIND_COUNTER,
   "tokens decoded this step", engines=("serve",), unit="tokens")
_r("serve/slot_occupancy", KIND_GAUGE,
   "decode slots holding a live request after this step",
   engines=("serve",), unit="slots")
_r("serve/pages_in_use", KIND_GAUGE,
   "KV pages allocated out of the pool after this step",
   engines=("serve",), unit="pages")
_r("serve/tokens_per_s", KIND_GAUGE,
   "measured decode throughput (host wall clock, filled at drain)",
   engines=("serve",), unit="tok/s")


def age_hist_len(fed_cfg) -> int:
    """Static retry-age histogram length: ages 1..max_retries (a row
    older than its budget is abandoned, never buffered)."""
    return max(int(getattr(fed_cfg, "async_max_retries", 0)), 1)


def shape_for(spec: CounterSpec, fed_cfg) -> Tuple[int, ...]:
    if spec.name == "buffer/age_hist":
        return (age_hist_len(fed_cfg),)
    return spec.shape


def specs_for(engine: str) -> Dict[str, CounterSpec]:
    """The registry slice one engine publishes."""
    return {n: s for n, s in REGISTRY.items() if engine in s.engines}


def init_column(engine: str, fed_cfg) -> Dict[str, jnp.ndarray]:
    """The carry column: one zeroed f32 array per registered signal.
    A flat dict-of-arrays pytree — it rides the scan carry and donates
    like any other state field."""
    return {n: jnp.zeros(shape_for(s, fed_cfg), jnp.float32)
            for n, s in specs_for(engine).items()}


def accumulate(tele: Dict[str, jnp.ndarray],
               round_values: Dict[str, jnp.ndarray],
               engine: str) -> Dict[str, jnp.ndarray]:
    """Fold one round's instantaneous values into the carry column:
    counters add, gauges overwrite.  ``round_values`` must cover every
    registered signal of the engine (init_column's keys)."""
    specs = specs_for(engine)
    out = {}
    for name, spec in specs.items():
        v = jnp.asarray(round_values[name], jnp.float32)
        out[name] = tele[name] + v if spec.kind == KIND_COUNTER else v
    return out


def metric_keys(round_values: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Per-round metrics entries: ``obs/<name>`` -> f32 array.  These
    stack through the scan like every other metric and drain once per
    chunk."""
    return {METRIC_PREFIX + n: jnp.asarray(v, jnp.float32)
            for n, v in round_values.items()}


def quantiles(x: jnp.ndarray) -> jnp.ndarray:
    """[p10, p50, p90] gauge of a cohort column."""
    return jnp.quantile(x.astype(jnp.float32),
                        jnp.asarray(QUANTILE_PROBS, jnp.float32))


def age_histogram(age: jnp.ndarray, active: jnp.ndarray,
                  fed_cfg) -> jnp.ndarray:
    """Active buffered rows bucketed by retry age: bucket i counts rows
    aged i+1 (ages start at 1 when a row parks)."""
    n = age_hist_len(fed_cfg)
    buckets = jnp.arange(1, n + 1)
    onehot = (age[:, None] == buckets[None, :]).astype(jnp.float32)
    return (onehot * active[:, None]).sum(axis=0)


def row_obs(row: dict) -> dict:
    """The ``obs/`` slice of one drained history row, prefix stripped."""
    return {k[len(METRIC_PREFIX):]: v for k, v in row.items()
            if isinstance(k, str) and k.startswith(METRIC_PREFIX)}
