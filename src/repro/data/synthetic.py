"""Synthetic dataset generators (the container is offline, so class-
conditional generators stand in for MNIST / X-ray / Crop / LM corpora —
same shapes, controllable difficulty and heterogeneity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_tabular(key, n, n_features=22, n_classes=22, sep=2.0):
    """Crop-Recommendation-like: Gaussian blobs in feature space."""
    kc, km, kx = jax.random.split(key, 3)
    centers = sep * jax.random.normal(km, (n_classes, n_features))
    y = jax.random.randint(kc, (n,), 0, n_classes)
    x = centers[y] + jax.random.normal(kx, (n, n_features))
    return x.astype(jnp.float32), y.astype(jnp.int32)


def make_images(key, n, size=28, n_classes=10, sep=1.5):
    """MNIST/X-ray-like: per-class low-rank template + pixel noise,
    values in [0, 1], shape (n, size, size, 1)."""
    kt, kc, kx = jax.random.split(key, 3)
    rank = 4
    u = jax.random.normal(kt, (n_classes, size, rank))
    v = jax.random.normal(jax.random.fold_in(kt, 1), (n_classes, rank, size))
    templates = jnp.einsum("csr,crt->cst", u, v) / jnp.sqrt(rank)
    y = jax.random.randint(kc, (n,), 0, n_classes)
    x = sep * templates[y] + jax.random.normal(kx, (n, size, size))
    x = jax.nn.sigmoid(x)[..., None]
    return x.astype(jnp.float32), y.astype(jnp.int32)


def make_lm_tokens(key, n_seqs, seq_len, vocab, n_latent=32):
    """Synthetic LM corpus: mixture-of-Markov-chains token streams.

    Each sequence follows one latent chain whose transition rows are sparse
    — learnable structure so a ~100M model's loss actually decreases.
    """
    kz, kt, kw = jax.random.split(key, 3)
    z = jax.random.randint(kz, (n_seqs,), 0, n_latent)
    # per-latent sparse "next token" tables: vocab -> 8 candidates
    cand = jax.random.randint(kt, (n_latent, vocab, 8), 0, vocab)

    def gen_seq(zi, k):
        def step(tok, kk):
            nxt = cand[zi, tok, jax.random.randint(kk, (), 0, 8)]
            return nxt, nxt

        k0, ks = jax.random.split(k)
        first = jax.random.randint(k0, (), 0, vocab)
        _, toks = jax.lax.scan(step, first,
                               jax.random.split(ks, seq_len - 1))
        return jnp.concatenate([first[None], toks])

    keys = jax.random.split(kw, n_seqs)
    return jax.vmap(gen_seq)(z, keys).astype(jnp.int32)
