"""Federated data pipeline: builds client-stacked federations and serves
per-round minibatches (the SimEngine's data_fn contract).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import partition, synthetic


class Federation:
    """Client-stacked dataset living on device; samples per-round batches."""

    def __init__(self, stacked: Dict[str, np.ndarray], batch_size: int,
                 eval_batch: int = 0):
        self.data = {k: jnp.asarray(v) for k, v in stacked.items()}
        self.K = int(stacked["x"].shape[0])
        self.cap = int(stacked["x"].shape[1])
        self.ecap = int(stacked["eval_x"].shape[1])
        self.batch_size = min(batch_size, self.cap)
        self.eval_batch = min(eval_batch or self.ecap, self.ecap)

        @jax.jit
        def _sample(rng):
            kb, ke = jax.random.split(rng)
            bi = jax.random.randint(kb, (self.K, self.batch_size), 0, self.cap)
            ei = jax.random.randint(ke, (self.K, self.eval_batch), 0, self.ecap)
            take = lambda arr, idx: jax.vmap(lambda a, i: a[i])(arr, idx)
            return {
                "x": take(self.data["x"], bi),
                "y": take(self.data["y"], bi),
                "eval_x": take(self.data["eval_x"], ei),
                "eval_y": take(self.data["eval_y"], ei),
                "n": self.data["n"],
            }

        self._sample = _sample

    def data_fn(self, round_idx, rng):
        return self._sample(rng)


def build_federation(seed, *, kind="images", n=4000, n_clients=16,
                     dirichlet_alpha=0.3, batch_size=32, eval_batch=32,
                     n_classes=10, n_features=22, holdout=512, sep=None):
    """Returns (Federation, server_testset dict). kind: images|tabular."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    if kind == "images":
        x, y = synthetic.make_images(key, n + holdout, n_classes=n_classes,
                                     sep=sep if sep is not None else 1.5)
    else:
        x, y = synthetic.make_tabular(key, n + holdout,
                                      n_features=n_features,
                                      n_classes=n_classes,
                                      sep=sep if sep is not None else 2.0)
    x, y = np.asarray(x), np.asarray(y)
    test = {"x": jnp.asarray(x[n:]), "y": jnp.asarray(y[n:])}
    parts = partition.dirichlet_partition(rng, y[:n], n_clients,
                                          dirichlet_alpha)
    stacked = partition.stack_clients(x[:n], y[:n], parts)
    return Federation(stacked, batch_size, eval_batch), test
