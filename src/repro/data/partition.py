"""Non-IID client partitioning (Dirichlet label skew, size skew) — the
paper's heterogeneity model ("partitioned using Dirichlet distributions").

Outputs client-stacked fixed-capacity arrays (K, cap, ...) + true sizes
(K,) so the whole federation is one jittable pytree.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(rng: np.random.Generator, labels: np.ndarray,
                        n_clients: int, alpha: float):
    """Returns a list of index arrays, one per client (label-skewed)."""
    n_classes = int(labels.max()) + 1
    idx_by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    client_idx = [[] for _ in range(n_clients)]
    for c, idx in enumerate(idx_by_class):
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            client_idx[k].extend(part.tolist())
    out = []
    for k in range(n_clients):
        a = np.asarray(client_idx[k], dtype=np.int64)
        rng.shuffle(a)
        if len(a) == 0:                     # guarantee non-empty clients
            a = np.array([rng.integers(0, len(labels))], dtype=np.int64)
        out.append(a)
    return out


def stack_clients(x: np.ndarray, y: np.ndarray, parts, *, eval_frac=0.2,
                  cap=None):
    """Fixed-capacity stacked federation arrays.

    Returns dict(x (K,cap,...), y (K,cap), eval_x (K,ecap,...), eval_y,
    n (K,)) — short clients are padded by cycling their own data (n holds
    the true size so q_k stays correct).
    """
    K = len(parts)
    sizes = np.array([len(p) for p in parts])
    cap = cap or int(sizes.max())
    e_sizes = np.maximum((sizes * eval_frac).astype(int), 1)
    t_sizes = np.maximum(sizes - e_sizes, 1)
    ecap = max(int(e_sizes.max()), 1)

    def take(idx, count, capacity):
        sub = idx[:count]
        if len(sub) == 0:           # degenerate (single-sample) client
            sub = idx if len(idx) else np.array([0], dtype=np.int64)
        reps = int(np.ceil(capacity / len(sub)))
        return np.tile(sub, reps)[:capacity]

    xs, ys, exs, eys = [], [], [], []
    for k, p in enumerate(parts):
        tr = take(p, t_sizes[k], cap)
        ev = take(p[t_sizes[k]:], e_sizes[k], ecap)
        xs.append(x[tr]); ys.append(y[tr])
        exs.append(x[ev]); eys.append(y[ev])
    return {
        "x": np.stack(xs), "y": np.stack(ys),
        "eval_x": np.stack(exs), "eval_y": np.stack(eys),
        "n": t_sizes.astype(np.float32),
    }


def size_skew_partition(rng: np.random.Generator, n_total: int,
                        n_clients: int, zipf_a: float = 1.3):
    """Zipf-distributed client sizes (for data-quality q_k experiments)."""
    raw = 1.0 / np.arange(1, n_clients + 1) ** zipf_a
    sizes = np.maximum((raw / raw.sum() * n_total).astype(int), 2)
    idx = rng.permutation(n_total)
    cuts = np.cumsum(sizes)[:-1]
    return [p for p in np.split(idx, cuts)][:n_clients]
