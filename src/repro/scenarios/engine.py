"""Turns one registry ``Scenario`` into a run of the SimEngine and a
robustness/fairness summary row.

Attacks bind to the round loop through the existing ``make_round`` hooks
(data_attack / update_attack), faults through the ``faults`` FaultConfig
— so a scenario run exercises exactly the code path every other
experiment uses, scan driver included.  Backdoor trigger accuracy is
tracked per round for EVERY scenario (the trigger-stamped server test
set scored against the backdoor target class): for non-backdoor cells it
stays at the target-class base rate, which is the regression signal.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.core import async_engine, attacks, fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build
from repro.obs import MemorySink, Telemetry
from repro.scenarios import registry


def make_attack_fns(sc, fed_cfg, n_classes):
    """(data_attack, update_attack) closures for one scenario cell."""
    data_attack = update_attack = None
    a = sc.attack
    if a == "label_flip":
        def data_attack(data, mal, rng):
            return {"y": attacks.label_flip(data["y"], n_classes, mal)}
    elif a == "backdoor":
        def data_attack(data, mal, rng):
            x, y = attacks.backdoor_trigger(
                data["x"], data["y"], mal, target=sc.backdoor_target,
                patch=sc.backdoor_patch)
            return {"x": x, "y": y}
    elif a == "sign_flip":
        def update_attack(upd, mal, rng):
            return attacks.sign_flip(upd, mal, scale=sc.attack_scale)
    elif a == "gaussian":
        def update_attack(upd, mal, rng):
            return attacks.gaussian_update(upd, mal, sc.attack_scale, rng)
    elif a == "scale":
        def update_attack(upd, mal, rng):
            return attacks.scale_attack(upd, mal, sc.attack_scale)
    elif a == "alie":
        def update_attack(upd, mal, rng):
            return attacks.alie(upd, mal, z=sc.alie_z)
    elif a in ("min_max", "min_sum"):
        fn = getattr(attacks, a)

        def update_attack(upd, mal, rng):
            return fn(upd, mal)
    elif a == "gate_aware":
        def update_attack(upd, mal, rng):
            return attacks.gate_aware(upd, mal, fed_cfg)
    elif a == "cross_round":
        # stateful: the round engines detect .stateful and thread the
        # (blend, prev_gated) carry through the scan (FedState.attacker)
        update_attack = attacks.CrossRoundGateAware(fed_cfg)
    elif a != "none":
        raise ValueError(f"unknown attack {a!r}")
    return data_attack, update_attack


def run_scenario(scenario, *, n_clients=10, n_rounds=10, seed=0,
                 kind="tabular", n=1600, n_classes=10, sep=1.0,
                 dirichlet_alpha=1.0, arch=None, driver="scan",
                 chunk_rounds=4, population=None, async_deadline=None,
                 telemetry=None):
    """Run one scenario cell; returns (summary dict, per-round history).

    ``population`` / ``async_deadline`` (the launch CLI's --population /
    --async-deadline) force the cell through the buffered-async engine
    with that registered-client count / round deadline, overriding the
    scenario's own async settings.  Async cells (``sc.async_mode``)
    sample a cohort of ``n_clients`` per round from the M-row
    ClientStore; ``n_clients`` is the COHORT size, not the population.

    ``telemetry``: a ``repro.obs.Telemetry``; by default every cell gets
    one with an in-memory sink, so the summary row always carries the
    drift-monitor outcome (``obs_warnings``/``obs_rows``).  Pass your
    own to route the cell's metric stream to JSONL/stdout sinks or a
    ``--trace`` Perfetto file; pass ``telemetry=False`` to opt out
    entirely (the engines then run the telemetry-free program).

    ``sep`` defaults below the pipeline's easy-mode class separation: on
    the trivially-separable default every aggregator reaches ~1.0 within
    a couple of rounds and attack degradation has no headroom to show.
    ``dirichlet_alpha`` defaults milder than the pipeline's 0.3: under
    heavy label skew the honest updates' own spread is so wide that any
    within-spread attacker (gate_aware, ALIE) gets a huge free budget
    and every aggregator degrades — 1.0 keeps the honest cluster tight
    enough that robust-aggregator margins are attributable to the
    attack, not the heterogeneity (which has its own fault-injection
    axis).
    """
    sc = registry.get(scenario) if isinstance(scenario, str) else scenario
    if (population or async_deadline) and sc.compress != "none":
        raise ValueError(
            f"scenario {sc.name!r} uses compress={sc.compress!r}, which "
            "the buffered-async engine does not support — drop "
            "--population/--async-deadline (the sync engine runs every "
            "codec cell) or pick a dense-uplink scenario (e.g. "
            "async_hetero)")
    if population or async_deadline:
        sc = sc.replace(
            async_mode=True, population=population or sc.population,
            fed=sc.fed + ((("async_deadline", float(async_deadline)),)
                          if async_deadline else ()))
    fed_cfg = sc.fed_config(n_clients)
    # async cells register a POPULATION of clients and sample the cohort
    pop = (sc.population or 3 * n_clients) if sc.async_mode else n_clients
    if sc.async_mode:
        fed_cfg = dataclasses.replace(fed_cfg, population=pop)
    model = build(ARCHS[arch or
                        ("paper-cnn" if kind == "images" else "paper-mlp")])
    federation, server_test = build_federation(
        seed, kind=kind, n=n, n_clients=pop, batch_size=32,
        n_classes=n_classes, sep=sep, dirichlet_alpha=dirichlet_alpha)

    n_mal = max(int(round(sc.mal_frac * pop)), 1) \
        if sc.attack != "none" else 0
    malicious = jnp.zeros((pop,)).at[jnp.arange(n_mal)].set(1.0) \
        if n_mal else None
    data_attack, update_attack = make_attack_fns(sc, fed_cfg, n_classes)

    trig_test = {"x": attacks.stamp_trigger(server_test["x"],
                                            patch=sc.backdoor_patch),
                 "y": server_test["y"]}

    @jax.jit
    def eval_fn(params):
        _, m = model.loss(params, server_test)
        logits = model.forward(params, trig_test)
        trig_acc = (logits.argmax(-1) == sc.backdoor_target).mean()
        return {"test_acc": m["acc"], "trigger_acc": trig_acc}

    if telemetry is None:
        telemetry = Telemetry(sinks=[MemorySink()], run_name=sc.name)
    elif telemetry is False:
        telemetry = None
    t0 = time.time()
    if sc.async_mode:
        state, hist = async_engine.run_async(
            model, fed_cfg, federation.data, n_rounds,
            jax.random.PRNGKey(seed + 1), eval_fn=eval_fn,
            batch_size=federation.batch_size,
            eval_batch=federation.eval_batch,
            data_attack=data_attack, update_attack=update_attack,
            malicious=malicious, faults=sc.faults,
            straggler_rows=sc.straggler_rows, driver=driver,
            chunk_rounds=chunk_rounds, telemetry=telemetry)
    else:
        state, hist = fedfits.run(
            model, fed_cfg, federation.data_fn, n_rounds,
            jax.random.PRNGKey(seed + 1), eval_fn=eval_fn,
            data_attack=data_attack, update_attack=update_attack,
            malicious=malicious, faults=sc.faults, driver=driver,
            chunk_rounds=chunk_rounds, telemetry=telemetry)
    wall = time.time() - t0
    summary = summarize(sc, state, hist, n_mal, wall)
    if telemetry is not None:
        obs = telemetry.finish()
        summary["obs_rows"] = obs["rows"]
        summary["obs_warnings"] = obs["n_warnings"]
        summary["obs_warning_counts"] = obs["warnings"]
    return summary, hist


def summarize(sc, state, hist, n_mal, wall_s):
    """One robustness/* row: accuracy, trigger accuracy, fairness, trust
    separation, and cost for a finished scenario run."""
    accs = [float(h["test_acc"]) for h in hist]
    trig = [float(h["trigger_acc"]) for h in hist]
    last = hist[-1]
    gt = jnp.asarray(state.gate_trust)
    mal_mask = jnp.arange(gt.shape[0]) < n_mal
    return {
        "name": f"robustness/{sc.name}",
        "attack": sc.attack, "aggregator": sc.aggregator,
        "algorithm": sc.algorithm, "compress": sc.compress,
        "faults_active": sc.faults.active, "n_malicious": n_mal,
        "rounds": len(hist),
        "final_acc": accs[-1], "best_acc": max(accs),
        "final_trigger_acc": trig[-1], "max_trigger_acc": max(trig),
        "fair_acc_var": float(last["fair_acc_var"]),
        "fair_worst_decile": float(last["fair_worst_decile"]),
        "fair_part_gini": float(last["fair_part_gini"]),
        "gated_frac_mean": float(jnp.mean(jnp.asarray(
            [h["gated_frac"] for h in hist]))),
        "gate_trust_malicious": (
            float(jnp.where(mal_mask, gt, 0.0).sum() / n_mal)
            if n_mal else None),
        "gate_trust_honest": float(jnp.where(mal_mask, 0.0, gt).sum()
                                   / max(gt.shape[0] - n_mal, 1)),
        "cost_client_rounds": float(state.cost_client_rounds),
        "cost_bytes_up": float(state.cost_bytes_up),
        "wall_s": round(wall_s, 2),
    }
