"""Named robustness scenarios: one ``Scenario`` = one cell of the
attack x heterogeneity x compression x aggregator grid.

Curated cells live in ``SCENARIOS`` (the regression matrix
``benchmarks/bench_scenarios.py`` runs); ``smoke_grid()`` generates the
CI smoke matrix {gate_aware, alie, none} x {trimmed_mean, krum, fedavg}
x {dropout on/off}.  Every cell is runnable by name through
``engine.run_scenario`` and the launch CLI's ``--scenario`` flag.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.configs.base import FedConfig
from repro.core.faults import FaultConfig

DATA_ATTACKS = ("label_flip", "backdoor")
UPDATE_ATTACKS = ("sign_flip", "gaussian", "scale",
                  "alie", "min_max", "min_sum", "gate_aware",
                  "cross_round")
ATTACKS = ("none",) + DATA_ATTACKS + UPDATE_ATTACKS


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str = ""
    attack: str = "none"              # one of ATTACKS
    mal_frac: float = 0.3             # paper-style 30% byzantine
    aggregator: str = "trimmed_mean"  # fedavg|median|trimmed_mean|krum
    algorithm: str = "fedavg"         # selection algorithm; the attack x
                                      # aggregator cells default to full
                                      # participation so the matrix
                                      # isolates AGGREGATION robustness
                                      # (a fitness election that shrinks
                                      # the cohort below ~2*colluders
                                      # un-sizes any trimmed defense —
                                      # that interaction gets its own
                                      # fedfits cells)
    compress: str = "none"            # uplink codec (repro/comm/)
    faults: FaultConfig = field(default_factory=FaultConfig)
    backdoor_target: int = 0
    backdoor_patch: int = 3
    attack_scale: float = 10.0        # sign_flip / scale_attack boost
    alie_z: float = 4.0               # ALIE deviation (None -> the paper's
                                      # median-evasion prescription, which
                                      # is tuned for median defenses and
                                      # near-invisible to plain fedavg)
    # buffered-async cells (core/async_engine.py)
    async_mode: bool = False          # route through the async engine:
                                      # cohort of n_clients SAMPLED from a
                                      # population-scale ClientStore, late
                                      # deliveries retried via the buffer
    population: int = 0               # registered clients M (0 -> engine
                                      # default of 3x the cohort)
    straggler_rows: str = "tail"      # chronic-straggler placement; "head"
                                      # makes the malicious rows (always
                                      # the first ones) the stragglers —
                                      # the late-poison evasion channel
    fed: Tuple[Tuple[str, object], ...] = ()  # extra FedConfig overrides

    def fed_config(self, n_clients: int) -> FedConfig:
        """Defense sized to the declared threat model: trim_frac and
        krum_f cover ``mal_frac`` colluders (a trimmed mean that trims
        fewer rows per side than there are colluders, or a Krum scoring
        window that counts colluder-to-colluder zeros, is a
        misconfiguration, not a defense)."""
        n_mal = max(int(round(self.mal_frac * n_clients)), 1)
        kw = dict(trim_frac=max(0.2, self.mal_frac),
                  krum_f=n_mal, **dict(self.fed))
        return FedConfig(n_clients=n_clients, algorithm=self.algorithm,
                         aggregator=self.aggregator, compress=self.compress,
                         local_epochs=2, local_lr=0.2, **kw)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


_DROPOUT = FaultConfig(dropout_prob=0.3)
_HETERO = FaultConfig(straggler_frac=0.25, straggler_delay=3.0,
                      partial_min_frac=0.5)
# async cells: 30% chronic stragglers racing the round deadline, everyone
# else mildly delayed — the graceful-degradation regime
_LATE = FaultConfig(straggler_frac=0.3, straggler_delay=3.0,
                    base_delay=0.3)

SCENARIOS: Dict[str, Scenario] = {s.name: s for s in [
    # ---- baselines --------------------------------------------------
    Scenario("clean_fedavg", "no attack, plain mean",
             attack="none", aggregator="fedavg"),
    Scenario("clean_trimmed", "no attack, trimmed-mean defense"),
    # ---- adaptive attackers vs the aggregator zoo -------------------
    Scenario("alie_fedavg", "ALIE colluders vs undefended mean",
             attack="alie", aggregator="fedavg"),
    Scenario("alie_trimmed", "ALIE vs trimmed mean", attack="alie"),
    Scenario("alie_krum", "ALIE vs Krum", attack="alie",
             aggregator="krum"),
    Scenario("gate_aware_fedavg", "defense-aware attacker vs plain mean",
             attack="gate_aware", aggregator="fedavg"),
    Scenario("gate_aware_trimmed", "defense-aware attacker vs its own "
             "defense", attack="gate_aware"),
    Scenario("gate_aware_krum", "defense-aware attacker vs Krum",
             attack="gate_aware", aggregator="krum"),
    Scenario("minmax_trimmed", "min-max distance attack vs trimmed mean",
             attack="min_max"),
    Scenario("minsum_trimmed", "min-sum distance attack vs trimmed mean",
             attack="min_sum"),
    # ---- targeted / static -----------------------------------------
    Scenario("backdoor_trimmed", "corner-trigger backdoor (trigger-"
             "accuracy tracked per round)", attack="backdoor"),
    Scenario("signflip_trimmed", "10x sign-flip vs trimmed mean",
             attack="sign_flip"),
    # ---- system heterogeneity ---------------------------------------
    Scenario("dropout_trimmed", "30% mid-round update loss, clean",
             faults=_DROPOUT),
    Scenario("hetero_fedfits", "chronic stragglers + partial local work "
             "under the fitness election", algorithm="fedfits",
             faults=_HETERO),
    # ---- selection-dynamics cells (fitness election under attack) ----
    Scenario("alie_fedfits", "ALIE vs the fitness election + trimmed "
             "mean (the cohort-shrinking interaction)",
             attack="alie", algorithm="fedfits"),
    Scenario("signflip_fedfits", "sign-flip vs the fitness election "
             "(gate_trust EWMA demotes gated clients)",
             attack="sign_flip", algorithm="fedfits"),
    # ---- compression cells (incl. the dropout+compression cell) -----
    Scenario("signflip_trimmed_int8", "sign-flip under the int8 uplink",
             attack="sign_flip", compress="int8"),
    Scenario("gate_aware_int8_dropout", "defense-aware attacker + int8 "
             "uplink + mid-round dropout", attack="gate_aware",
             compress="int8", faults=_DROPOUT),
    # ---- cross-round adaptive attacker (stateful; PR-5 follow-up) ----
    Scenario("cross_round_trimmed", "stateful attacker probing the gate "
             "across rounds (blend re-tuned from last round's gate "
             "outcome) vs trimmed mean", attack="cross_round"),
    # ---- buffered-async cells (population-scale ClientStore) ---------
    Scenario("async_hetero", "buffered-async engine, 30% chronic "
             "stragglers retried through the staleness-weighted buffer, "
             "clean", async_mode=True, faults=_LATE),
    Scenario("async_late_poison", "the colluders ARE the chronic "
             "stragglers (straggler_rows=head): their cross-round poison "
             "arrives LATE through the retry buffer at stale weight and "
             "must not evade the threat-sized trimmed mean",
             async_mode=True, attack="cross_round",
             straggler_rows="head", faults=_LATE),
    Scenario("async_late_poison_krum", "late-arriving stale-weight "
             "poison vs threat-sized Krum", async_mode=True,
             attack="cross_round", aggregator="krum",
             straggler_rows="head", faults=_LATE),
]}


def smoke_grid() -> Dict[str, Scenario]:
    """CI smoke matrix: {gate_aware, alie, none} x {trimmed_mean, krum,
    fedavg} x {dropout on/off} -> 18 cells named grid/<a>+<agg>[+drop],
    plus 4 buffered-async cells (async/<a>+<agg>) running the
    population-scale engine under 30% chronic stragglers."""
    cells = {}
    for atk in ("gate_aware", "alie", "none"):
        for agg in ("trimmed_mean", "krum", "fedavg"):
            for drop in (False, True):
                name = f"grid/{atk}+{agg}" + ("+drop" if drop else "")
                cells[name] = Scenario(
                    name, "CI smoke-grid cell", attack=atk, aggregator=agg,
                    faults=_DROPOUT if drop else FaultConfig())
    for atk, agg in (("none", "trimmed_mean"), ("none", "fedavg"),
                     ("sign_flip", "trimmed_mean"),
                     ("cross_round", "trimmed_mean")):
        name = f"async/{atk}+{agg}"
        cells[name] = Scenario(
            name, "CI async smoke cell", attack=atk, aggregator=agg,
            async_mode=True, faults=_LATE,
            straggler_rows="head" if atk != "none" else "tail")
    return cells


def all_scenarios() -> Dict[str, Scenario]:
    return {**SCENARIOS, **smoke_grid()}


def get(name: str) -> Scenario:
    table = all_scenarios()
    if name not in table:
        known = ", ".join(sorted(table))
        raise KeyError(f"unknown scenario {name!r}; known: {known}")
    return table[name]
