"""Scenario engine (ROADMAP item 5): named attack x heterogeneity x
compression x aggregator grid cells + the runner that turns one cell
into a robustness/fairness row.

  registry.get(name) / registry.all_scenarios()   the grid
  engine.run_scenario(name_or_scenario, ...)      one cell -> summary
"""
from repro.scenarios.engine import run_scenario, summarize  # noqa: F401
from repro.scenarios.registry import (SCENARIOS, Scenario,  # noqa: F401
                                      all_scenarios, get, smoke_grid)
