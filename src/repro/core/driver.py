"""Shared chunked ``lax.scan`` round driver for both FL engines.

Extracted from ``fedfits.run`` (PR 2's zero-copy scan loop) so the
simulation engine (core/fedfits.py) and the pod engine (core/pod.py)
drive multi-round training through ONE subsystem:

  * rounds run in ``chunk_steps``-sized ``jax.lax.scan`` chunks with the
    per-round metric history kept on device — ONE ``device_get`` per
    chunk instead of 2+ host syncs per round;
  * the chunk step DONATES its carry (``donate_argnums``) so
    params/opt-state update in place instead of allocating a fresh copy
    per chunk (batch buffers are pure inputs with nothing to alias, so
    they are not donated);
  * chunk batches are double-buffered: while chunk k computes, chunk
    k+1's batches are built on host and staged with an async
    ``jax.device_put`` so the host->device transfer overlaps compute;
  * **sharding-aware prefetch**: ``batch_sharding`` (a ``NamedSharding``
    tree matching ONE batch) makes ``stage_chunk`` put chunk k+1's
    stacked batches DIRECTLY onto their pod shards — the stacked
    (chunk, ...) buffers get the same sharding with a leading replicated
    chunk dim (``chunk_sharding``), so a sharded pod step reads its
    batch shard-locally instead of re-slicing a default-device copy
    (ROADMAP open item 3).

None of this changes numerics: a driver's history is bit-for-bit equal
to the per-step jitted python loop over the same body (parity-tested
for both engines).

PRNG aliasing footgun: the donated carry aliases whatever arrays the
caller built it from (e.g. the PRNG key stored in ``PodFedState.rng``).
The first chunk deletes those buffers, so any host-side sampler must
consume its key from a COPY taken before the first ``run`` call —
see ``launch/train.py`` and tests/test_driver.py.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def chunk_sharding(batch_sharding):
    """Lift a per-batch ``NamedSharding`` tree to the stacked
    (chunk, ...) layout: same mesh/spec with a leading replicated chunk
    dim.  The scan streams the chunk axis, so only the per-step slice's
    sharding matters — and it matches the per-batch sharding exactly."""
    def lift(s):
        if isinstance(s, NamedSharding):
            return NamedSharding(s.mesh, P(None, *s.spec))
        return s

    return jax.tree_util.tree_map(
        lift, batch_sharding,
        is_leaf=lambda x: isinstance(x, NamedSharding))


def _stack(*xs):
    """Stack one leaf across the chunk's batches.  Host-built (numpy)
    batches stack on HOST so the subsequent sharded ``device_put`` is a
    single host->shard transfer; device-resident batches stack with
    ``jnp`` (pulling them back to host would cost a device->host copy)
    and pay one device->shards redistribution hop instead."""
    if any(isinstance(x, jax.Array) for x in xs):
        return jnp.stack(xs)
    return np.stack(xs)


def stage_chunk(batch_fn, ts, batch_sharding=None):
    """Build the stacked batches for steps ``ts`` and start their
    host->device transfer (async ``jax.device_put``) — called while the
    PREVIOUS chunk is still computing, so the upload overlaps compute.
    With ``batch_sharding`` (the STACKED sharding from
    ``chunk_sharding``) every batch buffer lands on its pod shards —
    directly from host memory for host-built batches, via one
    redistribution hop for already-device-resident ones; otherwise it
    stages onto the default device."""
    batches = [dict(batch_fn(t)) for t in ts]
    stacked = jax.tree_util.tree_map(_stack, *batches)
    if batch_sharding is not None:
        stacked = jax.device_put(stacked, batch_sharding)
    else:
        stacked = jax.device_put(stacked)
    return jnp.asarray(ts, jnp.int32), stacked


class ScanDriver:
    """Reusable chunked-scan driver around ``body(state, (t, batch)) ->
    (state, metrics)``.  The jitted chunk scan is built once, so repeated
    ``run`` calls (benchmarks, restarts) hit the jit cache."""

    def __init__(self, body: Callable, *, chunk_steps: int = 8,
                 batch_sharding=None, donate: bool = True):
        self.chunk_steps = int(chunk_steps)
        self._put_sharding = (chunk_sharding(batch_sharding)
                              if batch_sharding is not None else None)
        donate_argnums = (0,) if donate else ()

        def scan_chunk(st, ts, batches):
            return jax.lax.scan(body, st, (ts, batches))

        self._scan = jax.jit(scan_chunk, donate_argnums=donate_argnums)

    def stage(self, batch_fn, ts):
        return stage_chunk(batch_fn, ts, self._put_sharding)

    def run(self, state, batch_fn, n_steps, *, t0: int = 0,
            index_key: str = "step",
            on_chunk: Optional[Callable[[Any, list], None]] = None,
            telemetry=None):
        """Drive ``n_steps`` steps starting at ``t0``.  ``batch_fn(t)``
        is a host callable returning one batch dict.  Returns
        ``(final_state, history)`` — one row dict per step, each carrying
        its step index under ``index_key``.  ``on_chunk(state, rows)``
        fires after every chunk (logging / checkpoint hook).

        ``telemetry`` (an ``repro.obs.Telemetry``) observes the drained
        rows at the same boundary and — when tracing — gets the host-
        MEASURED per-chunk window (dispatch -> drain; the existing
        ``device_get`` is the sync point, so tracing adds none)."""
        end = t0 + n_steps

        def steps_of(s0):
            return list(range(s0, min(s0 + self.chunk_steps, end)))

        history = []
        if telemetry is not None:
            telemetry.begin("stage")
        pending = (steps_of(t0), *self.stage(batch_fn, steps_of(t0))) \
            if n_steps >= 1 else None
        if telemetry is not None:
            telemetry.end("stage", steps=len(pending[0]) if pending else 0)
        next_t0 = t0 + self.chunk_steps
        while pending is not None:
            ts, ts_dev, stacked = pending
            w0 = telemetry.now_us() if telemetry is not None else 0.0
            # dispatch is async: the scan runs while the next chunk stages
            state, mets = self._scan(state, ts_dev, stacked)
            if telemetry is not None and next_t0 < end:
                telemetry.begin("stage")
            pending = (steps_of(next_t0),
                       *self.stage(batch_fn, steps_of(next_t0))) \
                if next_t0 < end else None
            if telemetry is not None and pending is not None:
                telemetry.end("stage", steps=len(pending[0]))
            next_t0 += self.chunk_steps
            mets = jax.device_get(mets)            # one sync per chunk
            w1 = telemetry.now_us() if telemetry is not None else 0.0
            rows = []
            for j, t in enumerate(ts):
                row = {k: v[j] for k, v in mets.items()}
                row[index_key] = t
                rows.append(row)
            if telemetry is not None:
                # the measured chunk window: scan dispatch through metric
                # drain; per-round phase spans inside it are attributed
                # (see obs/trace.py)
                if telemetry.tracer is not None:
                    telemetry.tracer.span(
                        "chunk", w0, w1 - w0, tid=0,
                        steps=len(ts), first=ts[0], last=ts[-1])
                telemetry.observe_rows(rows, w0, w1 - w0)
            if on_chunk is not None:
                on_chunk(state, rows)
            history.extend(rows)
        return state, history


def run_chunked(body, state, batch_fn, n_steps, *, chunk_steps=8, t0=0,
                batch_sharding=None, index_key="step", on_chunk=None,
                donate=True, telemetry=None):
    """One-shot convenience wrapper: build a ``ScanDriver`` and run it."""
    drv = ScanDriver(body, chunk_steps=chunk_steps,
                     batch_sharding=batch_sharding, donate=donate)
    return drv.run(state, batch_fn, n_steps, t0=t0, index_key=index_key,
                   on_chunk=on_chunk, telemetry=telemetry)
