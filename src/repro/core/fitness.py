"""FedFiTS fitness metrics (paper §III-A, §V) — pure jnp, fully jittable.

  theta_k     Eq. (1): Quality-of-Learning angle between the (loss, acc)
              midpoint of global/local models and the loss unit vector.
  score_k     Eq. (2): alpha * q_k + (1 - alpha) * theta_k.
  threshold   Eq. (3): mean(score) * (1 - beta).
  dynamic alpha  Eqs. (18)-(19): alpha_k = 1[q_k > theta_k]; alpha = mean_k.
              (The paper prints "sum"; the stated property alpha > 0.5 iff
               #(q_k > theta_k) > #(q_k < theta_k) requires the mean —
               see DESIGN.md §7.)

All functions take a client-availability mask so unavailable clients never
contribute to means/thresholds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def theta(gl, ga, ll, la, *, paper_exact=False):
    """Eq. (1). All args (K,) float32: global/local loss & accuracy.

    Geometric intent (paper Fig. 1a): theta_k is the angle between the
    loss axis and the midpoint M((GL+LL)/2, (GA+LA)/2) of the global/local
    performance points, i.e.

        theta_k = arccos((GL+LL) / sqrt((GL+LL)^2 + (GA+LA)^2)).

    The equation as *printed* groups the terms per-point,
    sqrt((GL+GA)^2 + (LL+LA)^2), which exceeds the arccos domain whenever
    losses dominate (theta degenerates to 0 for any high-loss regime, e.g.
    LM training) — a typo by the geometric construction. We default to the
    geometry; ``paper_exact=True`` reproduces the literal formula
    (clipped), for A/B. See DESIGN.md §7.
    """
    num = gl + ll
    if paper_exact:
        den = jnp.sqrt(jnp.square(gl + ga) + jnp.square(ll + la))
    else:
        den = jnp.sqrt(jnp.square(gl + ll) + jnp.square(ga + la))
    arg = jnp.clip(num / jnp.maximum(den, _EPS), -1.0, 1.0)
    return jnp.arccos(arg)


def data_quality(n_k, mask=None):
    """q_k = n_k / n over available clients."""
    n_k = n_k.astype(jnp.float32)
    if mask is not None:
        n_k = n_k * mask
    return n_k / jnp.maximum(n_k.sum(), _EPS)


def score(q, th, alpha):
    """Eq. (2)."""
    return alpha * q + (1.0 - alpha) * th


def threshold(scores, beta, mask=None):
    """Eq. (3): mean of available clients' scores * (1 - beta)."""
    if mask is None:
        mask = jnp.ones_like(scores)
    mean = (scores * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return mean * (1.0 - beta)


def dynamic_alpha(q, th, mask=None):
    """Eqs. (18)-(19): alpha = mean_k 1[q_k > theta_k] over available clients."""
    if mask is None:
        mask = jnp.ones_like(q)
    ind = (q > th).astype(jnp.float32) * mask
    return ind.sum() / jnp.maximum(mask.sum(), 1.0)


def team_theta(th, team_mask):
    """theta(t) = sum_{k in S_t} theta_k (Algorithm 1)."""
    return (th * team_mask).sum()
