"""FedFiTS core: the paper's contribution as composable JAX modules."""
from repro.core import (aggregation, attacks, fitness, pod, selection,
                        slots)
from repro.core.fedfits import FedState, init_state, make_round, run
from repro.core.pod import PodState, init_pod_state, make_train_step
