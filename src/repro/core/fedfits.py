"""FedFiTS simulation engine — the paper-faithful Algorithm 1 + 2.

Per-client model replicas via ``vmap`` (cross-silo semantics: E local SGD
epochs per round from the global model, fitness evaluation on a client-local
test split, threshold election, slotted teams, trust-aware robust
aggregation). This engine drives the paper's experiments (EXPERIMENTS.md
§Paper-faithful) at the paper's own model scale; the pod-scale SPMD mapping
for the big architectures lives in core/pod.py.

Simulation note: every available client is *computed* each round (vmap is
SPMD-uniform), but only clients Algorithm 1 would actually train are
counted in the communication/compute cost metrics — `cost_client_rounds`
matches the paper's accounting (FFA rounds bill all clients, slot rounds
bill only the team).

Transport: with `FedConfig.compress` the client->server boundary runs
through the comm subsystem (repro/comm/) — updates cross the wire
encoded (EF residuals in the scan carry), the int8 path aggregates
straight from the codes (fused dequant kernels), and
`cost_bytes_up/down` bill the MEASURED wire sizes instead of an
analytic 2*|params|*4 model.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm import codecs as comm_codecs, error_feedback
from repro.core import aggregation, attacks, clientstore, \
    driver as scan_driver, fairness, faults as faults_mod, fitness, \
    selection, slots
from repro.obs import counters as obs_counters
from repro.obs.trace import annotate as obs_annotate


class FedState(NamedTuple):
    """Round carry of the synchronous engine.  Per-client persistent
    columns (trust tracks, cum_selected, EF residuals, staleness,
    failure counts) live in the nested ``clients`` ClientStore — the
    sync engine is the M == K special case of the population-scale
    store (core/clientstore.py); back-compat properties keep the old
    ``state.trust`` / ``state.gate_trust`` / ``state.cum_selected`` /
    ``state.ef`` read paths working."""
    params: Any               # global model w(t-1)
    team: jnp.ndarray         # (K,) 0/1 mask S_t
    alpha: jnp.ndarray        # current alpha (dynamic or fixed)
    slot: slots.SlotState
    h: jnp.ndarray            # h(t): reselect this round?
    rng: jnp.ndarray
    round: jnp.ndarray        # t (1-indexed)
    cost_client_rounds: jnp.ndarray  # billed client-rounds (cost model)
    cost_bytes_up: jnp.ndarray    # MEASURED uplink bytes (encoded sizes)
    cost_bytes_down: jnp.ndarray  # MEASURED downlink bytes (dense model)
    clients: clientstore.ClientStore = None  # (K,) per-client columns
    attacker: Any = None      # stateful-attacker carry (cross-round
                              # adaptive attacks read last round's gate
                              # outcome from here; None = stateless)
    tele: Any = None          # telemetry carry column (repro/obs/):
                              # {counter name: f32 array}; None = obs off
                              # (the round body branches statically, so
                              # off-runs trace the exact pre-obs program)

    @property
    def trust(self):
        return self.clients.trust

    @property
    def gate_trust(self):
        return self.clients.gate_trust

    @property
    def cum_selected(self):
        return self.clients.cum_selected

    @property
    def ef(self):
        return self.clients.ef


def init_state(params, n_clients, fed_cfg, rng, *, attacker=None):
    store = clientstore.init_store(n_clients, params=params,
                                   fed_cfg=fed_cfg)
    att = attacker.init(n_clients) if attacker is not None else None
    return FedState(
        params=params,
        team=jnp.ones((n_clients,), jnp.float32),
        alpha=jnp.float32(fed_cfg.alpha),
        slot=slots.init_slot_state(),
        h=jnp.array(True),
        rng=rng,
        round=jnp.int32(1),
        cost_client_rounds=jnp.float32(0.0),
        cost_bytes_up=jnp.float32(0.0),
        cost_bytes_down=jnp.float32(0.0),
        clients=store,
        attacker=att,
    )


def make_client_update(model, fed_cfg):
    """Algorithm 2: E local epochs of SGD from w(t-1); returns the new local
    params and (GL, GA, LL, LA) evaluated on the client's test split.

    ``n_epochs`` (i32 scalar per vmapped client) is the client's EFFECTIVE
    epoch count (partial-work fault injection, core/faults.py): epochs past
    it still compute their gradient (the vmapped step stays SPMD-uniform,
    same as the availability simulation) but stop updating the parameters.
    With ``n_epochs == local_epochs`` the masking is the identity."""

    def client_update(params, data, rng, n_epochs):
        # data: {x, y, eval_x, eval_y, n} for ONE client
        def epoch(p, inp):
            _, i = inp

            def loss_fn(q):
                l, _ = model.loss(q, {"x": data["x"], "y": data["y"]})
                if fed_cfg.prox_mu:
                    # FedProx proximal term ||q - w(t-1)||^2 (Li et al.)
                    prox = sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                        jax.tree_util.tree_leaves(q),
                        jax.tree_util.tree_leaves(params)))
                    l = l + 0.5 * fed_cfg.prox_mu * prox
                return l

            g = jax.grad(loss_fn)(p)
            return jax.tree_util.tree_map(
                lambda w, gw: jnp.where(i < n_epochs,
                                        w - fed_cfg.local_lr * gw, w),
                p, g), None

        local, _ = jax.lax.scan(
            epoch, params,
            (jax.random.split(rng, fed_cfg.local_epochs),
             jnp.arange(fed_cfg.local_epochs)))

        gl, gmet = model.loss(params, {"x": data["eval_x"], "y": data["eval_y"]})
        ll, lmet = model.loss(local, {"x": data["eval_x"], "y": data["eval_y"]})
        return local, (gl, gmet["acc"], ll, lmet["acc"])

    return client_update


def make_round(model, fed_cfg, *, data_attack=None, update_attack=None,
               malicious=None, faults=None):
    """Builds the jittable one-round function.

    data_attack(batch_k_stacked, malicious, rng) -> corrupted batch
    update_attack(updates, malicious, rng) -> corrupted updates
    faults: optional ``faults.FaultConfig`` — system-heterogeneity
    injection (stragglers / mid-round dropout / partial local work).
    Fault draws come from keys folded off the round's own rng streams,
    so they live in the scan carry and scan==python parity holds.
    """
    client_update = make_client_update(model, fed_cfg)
    K = fed_cfg.n_clients
    mal = malicious if malicious is not None else jnp.zeros((K,), jnp.float32)
    codec = comm_codecs.make_codec(fed_cfg)
    stateful_attack = getattr(update_attack, "stateful", False)
    guard_on = getattr(fed_cfg, "update_guard", True)
    if faults is not None and not faults.active:
        faults_cfg = None                       # inactive == no injection
    else:
        faults_cfg = faults

    def round_fn(state: FedState, data):
        """data: client-stacked {x:(K,B,...), y:(K,B), eval_x, eval_y, n:(K,)}
        plus optional {avail:(K,)}."""
        rng, r_data, r_upd, r_sel, r_cli = jax.random.split(state.rng, 5)
        avail = data.get("avail", jnp.ones((K,), jnp.float32))
        t = state.round

        # ---- fault injection: stragglers miss the round deadline -------
        # a late client simply never arrives, so the straggle composes
        # with the whole availability path: selection, fitness masks, and
        # the stale_weight catch-up (a slot-team member that straggles
        # re-enters at stale weight, like any other unavailability)
        if faults_cfg is not None and faults_cfg.stragglers_active:
            avail = avail * faults_mod.sample_arrivals(
                faults_cfg, jax.random.fold_in(r_data, 11), K)

        if data_attack is not None:
            data = dict(data)
            data.update(data_attack(data, mal, r_data))

        # ---- local training (vmapped clients) --------------------------
        # partial-work fault: heterogeneous effective local epochs
        if faults_cfg is not None and faults_cfg.partial_active:
            eff_epochs = faults_mod.sample_epochs(
                faults_cfg, jax.random.fold_in(r_cli, 13), K,
                fed_cfg.local_epochs)
        else:
            eff_epochs = jnp.full((K,), fed_cfg.local_epochs, jnp.int32)
        keys = jax.random.split(r_cli, K)
        with obs_annotate("client_update"):
            locals_, (gl, ga, ll, la) = jax.vmap(
                client_update, in_axes=(None, 0, 0, 0))(state.params, data,
                                                        keys, eff_epochs)
        updates = jax.tree_util.tree_map(
            lambda w_k, w: w_k - w[None], locals_, state.params)

        att_carry = state.attacker
        if update_attack is not None:
            if stateful_attack:
                # cross-round adaptive attacker: reads last round's gate
                # outcome from the carry, re-tunes its blend, and hands
                # back the adapted carry (completed after the gate below)
                updates, att_carry = update_attack(
                    updates, mal, r_upd, state.attacker)
            else:
                updates = update_attack(updates, mal, r_upd)

        # ---- client->server transport (repro/comm/) ---------------------
        # the codec runs CLIENT-side, after the attacker corrupted its own
        # update: only the encoded wire format crosses the boundary, and
        # only its measured bytes are billed.  EF residuals re-inject last
        # round's compression error before encoding.
        enc, new_ef = None, state.ef
        if codec is not None:
            enc, dec, new_ef = error_feedback.compress(
                codec, updates, state.ef,
                # fold_in, not split: the existing rng streams (and with
                # them the compress="none" histories) stay untouched
                rng=jax.random.fold_in(r_upd, 7) if codec.stochastic
                else None)
            bytes_up_pc = comm_codecs.wire_bytes_per_client(enc)
            updates = dec
        else:
            bytes_up_pc = comm_codecs.dense_bytes_per_client(updates)
        bytes_down_pc = comm_codecs.param_bytes(state.params)

        # ---- fitness ----------------------------------------------------
        q = fitness.data_quality(data["n"], avail)
        th = jnp.where(t == 1, jnp.zeros((K,)), fitness.theta(gl, ga, ll, la))

        alpha = jnp.where(
            jnp.array(fed_cfg.dynamic_alpha),
            fitness.dynamic_alpha(q, th, avail), jnp.float32(fed_cfg.alpha))
        scores = fitness.score(q, th, alpha)
        if fed_cfg.trust_in_fitness:
            # dynamic client scoring: the cosine-gate trust EWMA scales
            # the fitness score, so repeatedly-gated clients stop being
            # elected.  gate_trust is exactly 1.0 until someone is gated,
            # keeping the fold behavior-preserving on clean runs.
            scores = scores * state.gate_trust

        # ---- selection (only when h(t): FFA/NAT rounds) ------------------
        with obs_annotate("selection"):
            if fed_cfg.algorithm == "fedfits":
                new_team = selection.fedfits_select(
                    scores, fed_cfg.beta, avail, r_sel,
                    floor_prob=fed_cfg.participation_floor,
                    explore_eps=fed_cfg.explore_eps)
                new_team = jnp.where(t == 1, avail, new_team)
                team = jnp.where(state.h, new_team, state.team * avail)
            elif fed_cfg.algorithm == "fedavg":
                team = selection.fedavg_select(avail)
            elif fed_cfg.algorithm == "fedrand":
                team = selection.fedrand_select(avail, fed_cfg.fedrand_c,
                                                r_sel)
            elif fed_cfg.algorithm == "fedpow":
                d = fed_cfg.fedpow_d or K
                m = fed_cfg.fedpow_m or max(K // 2, 1)
                team = selection.fedpow_select(gl, avail, d, m, r_sel,
                                               n=data["n"])
            else:
                raise ValueError(fed_cfg.algorithm)

        # ---- fault injection: mid-round dropout ------------------------
        # a SELECTED client computes its update (so it is still billed,
        # compute and uplink both — the loss is on the server side of the
        # wire) but the update never reaches the aggregate.  Dropped
        # clients are NOT stale catch-up contributors: stale covers
        # clients that never arrived, not updates lost in flight.
        if faults_cfg is not None and faults_cfg.dropout_active:
            lost = faults_mod.sample_dropout(
                faults_cfg, jax.random.fold_in(r_sel, 12), team)
        else:
            lost = jnp.zeros((K,), jnp.float32)
        delivered = team * (1.0 - lost)

        # ---- aggregation -------------------------------------------------
        # async catch-up (Table II gap 2): slot-team members that went
        # unavailable this round still contribute at stale_weight
        stale = fed_cfg.stale_weight * state.team * (1.0 - avail)
        part = jnp.clip(delivered + stale, 0.0, 1.0)

        # ---- aggregation-boundary guard --------------------------------
        # a crashed or hostile client delivering NaN/Inf or an
        # absurd-norm update is REJECTED here — zeroed, masked out of
        # every aggregation path (fused and reference), and penalised
        # via the gate-trust EWMA below — instead of poisoning the
        # global model.  On sane inputs the sanitise pass is a bitwise
        # identity, so clean histories are unchanged.  Billing uses the
        # PRE-rejection masks: the rejected client did the work and
        # crossed the wire (billed-but-lost, like mid-round dropout).
        part_pre, stale_pre = part, stale
        rejected = jnp.zeros((K,), jnp.float32)
        g_nonfinite = g_norm = jnp.float32(0.0)
        if guard_on:
            if state.tele is not None:
                # guard rejections split by kind — shares the guard's own
                # reductions (CSE), a pure readout
                nf, nr = aggregation.rejection_kinds(
                    updates, (part > 0).astype(jnp.float32),
                    norm_mult=fed_cfg.guard_norm_mult)
                g_nonfinite, g_norm = nf.sum(), nr.sum()
            with obs_annotate("sanitize"):
                updates, _, rejected = aggregation.sanitize_updates(
                    updates, (part > 0).astype(jnp.float32),
                    norm_mult=fed_cfg.guard_norm_mult)
            delivered = delivered * (1.0 - rejected)
            stale = stale * (1.0 - rejected)
            part = jnp.clip(delivered + stale, 0.0, 1.0)
        with obs_annotate("aggregate"):
            if fed_cfg.paper_exact_agg:
                # Algorithm 1's size-proportional FedAvg step.  The paper
                # writes n_k/|S_t|, but data["n"] carries REAL partition
                # sizes, so dividing raw counts by the team size would
                # scale the update by ~mean(n_k) (hundreds x); the convex
                # combination the algorithm means is
                # n_k / sum_{j in S_t} n_j
                w = data["n"].astype(jnp.float32) * delivered
                w = w / jnp.maximum(w.sum(), 1e-12)
                agg = jax.tree_util.tree_map(
                    lambda l: jnp.tensordot(w.astype(l.dtype), l,
                                            axes=(0, 0)),
                    updates)
            else:
                weights = data["n"].astype(jnp.float32) * state.trust \
                    * (delivered + stale)
                part_mask = (part > 0).astype(jnp.float32)
                from repro.comm.kernels import comm_codecs as dq
                if enc is not None and dq.should_fuse(codec, fed_cfg,
                                                      updates):
                    # server aggregates STRAIGHT from the int8 wire
                    # codes: dequant happens in VMEM inside the fused
                    # Eq.-11 passes (bit-identical to aggregating `dec`;
                    # ~4x less agg HBM)
                    agg = dq.fused_dequant_aggregate_tree(
                        enc, weights, part_mask, fed_cfg, like=updates,
                        blk=getattr(fed_cfg, "agg_blk", None))
                else:
                    agg = aggregation.aggregate(updates, weights,
                                                part_mask, fed_cfg)
        with obs_annotate("writeback"):
            new_params = jax.tree_util.tree_map(
                lambda p, u: p + u.astype(p.dtype), state.params, agg)

        # ---- slot & trust state ------------------------------------------
        theta_team = fitness.team_theta(th, team)
        new_slot, h_next = slots.update(state.slot, theta_team, t,
                                        fed_cfg.msl, fed_cfg.pft)
        new_trust = aggregation.update_trust(state.trust, scores, team,
                                             fed_cfg.trust_decay)

        # gate-trust EWMA (dynamic client scoring): participants whose
        # update points AWAY from the round's robust aggregate (cosine
        # below the gate threshold — the same rejection the Eq.-11
        # cosine gate applies in-kernel) see their trust decay toward 0;
        # clean participants decay toward 1, non-participants hold.
        cos = aggregation.cosine_to_ref(updates, agg)
        gated = ((cos < fed_cfg.cosine_outlier_thresh)
                 & (part > 0)).astype(jnp.float32)
        # guard rejections count as gate failures too: the EWMA runs
        # over PRE-rejection participants so a rejected delivery decays
        # trust exactly like a cosine-gated one (bad == gated when no
        # row was rejected, so clean histories are bit-identical)
        bad = jnp.maximum(gated, rejected)
        new_gate_trust = jnp.where(
            part_pre > 0,
            fed_cfg.trust_decay * state.gate_trust
            + (1.0 - fed_cfg.trust_decay) * (1.0 - bad),
            state.gate_trust)
        if stateful_attack:
            # complete the adaptive attacker's carry: it reads THIS
            # round's gate outcome next round
            att_carry = update_attack.observe(att_carry, bad)

        # cost accounting: FFA rounds bill every available client, slot
        # rounds the present team — PLUS, in both, the stale catch-up
        # clients: they went unavailable but still trained and submitted
        # an update at stale_weight, so their client-round is real work.
        # The paper-exact branch weighs by n_k * team only (no stale
        # contribution enters the aggregate), so nothing extra is billed.
        # Bytes are MEASURED, not modelled: every billed client-round
        # moves one dense model down and one ENCODED update up (the
        # actual wire sizes — dtype itemsizes, codes, scales, indices)
        billed = jnp.where(state.h, avail.sum(), team.sum())
        if not fed_cfg.paper_exact_agg:
            billed = billed + (stale_pre > 0).sum()

        # ---- telemetry readout (repro/obs/) -----------------------------
        # pure readouts of values the round already produced; nothing
        # downstream reads them back, so on/off runs are bit-identical
        new_tele, obs_metrics = state.tele, {}
        if state.tele is not None:
            wts = data["n"].astype(jnp.float32) * state.trust
            vals = {
                "gate/cosine_rejected": gated.sum(),
                "guard/nonfinite": g_nonfinite,
                "guard/norm": g_norm,
                "select/team_size": team.sum(),
                "select/available": avail.sum(),
                "agg/fresh_mass": (wts * delivered).sum(),
                "agg/stale_mass": (wts * stale).sum(),
                "cohort/trust_q": obs_counters.quantiles(new_trust),
                "cohort/gate_trust_q": obs_counters.quantiles(
                    new_gate_trust),
                "cohort/fitness_q": obs_counters.quantiles(scores),
                "wire/bytes_up": billed * bytes_up_pc,
                "wire/bytes_down": billed * bytes_down_pc,
                "fault/lost": lost.sum(),
            }
            new_tele = obs_counters.accumulate(state.tele, vals, "sync")
            obs_metrics = obs_counters.metric_keys(vals)
        new_clients = state.clients._replace(
            # fitness EWMA at compute time (the population-store prior;
            # the sync selection path keeps using the fresh scores, so
            # this column is bookkeeping, not a behavior change)
            fitness=fed_cfg.trust_decay * state.clients.fitness
            + (1.0 - fed_cfg.trust_decay) * scores,
            trust=new_trust,
            gate_trust=new_gate_trust,
            staleness=jnp.where(part > 0, 0, state.clients.staleness + 1),
            failures=state.clients.failures + rejected,
            cum_selected=state.clients.cum_selected + team,
            ef=new_ef)
        new_state = FedState(
            params=new_params, team=team, alpha=alpha,
            slot=new_slot, h=h_next, rng=rng, round=t + 1,
            cost_client_rounds=state.cost_client_rounds + billed,
            cost_bytes_up=state.cost_bytes_up + billed * bytes_up_pc,
            cost_bytes_down=state.cost_bytes_down + billed * bytes_down_pc,
            clients=new_clients, attacker=att_carry, tele=new_tele)
        metrics = {
            "theta": th, "score": scores, "team": team, "alpha": alpha,
            "theta_team": theta_team, "h_next": h_next,
            "global_loss_mean": (gl * avail).sum() / jnp.maximum(avail.sum(), 1),
            "local_loss_mean": (ll * avail).sum() / jnp.maximum(avail.sum(), 1),
            "team_size": team.sum(),
            # robustness / fairness block (scenario engine, ROADMAP item 5)
            "gate_trust": new_gate_trust,
            "gated_frac": gated.sum() / jnp.maximum(part.sum(), 1.0),
            "guard_rejected": rejected.sum(),
            "fault_lost": lost.sum(),
            "fault_eff_epochs": eff_epochs.astype(jnp.float32).mean(),
            **fairness.round_fairness(ga, avail, state.cum_selected + team),
            **obs_metrics,
        }
        return new_state, metrics

    return round_fn


def run(model, fed_cfg, data_fn, n_rounds, rng, *, eval_fn=None,
        data_attack=None, update_attack=None, malicious=None,
        faults=None, driver="scan", chunk_rounds=8, telemetry=None):
    """Drives n_rounds of FL. data_fn(round, rng) -> client-stacked batch.
    eval_fn(params) -> dict of server-side metrics (optional, per round).
    Returns (final_state, history list of dicts).

    driver="scan" (default): rounds run through the shared chunked-scan
    driver (core/driver.py — donated carry, on-device metric history,
    double-buffered batch staging; the pod engine drives multi-round
    training through the same subsystem).  data_fn stays a host
    callable; availability sampling moves inside the scan body (same
    fold_in streams), so the history is bit-for-bit identical to
    driver="python", the original per-round jit loop kept for parity
    testing."""
    r_init, r_run = jax.random.split(rng)
    params = model.init(r_init)
    att = update_attack if getattr(update_attack, "stateful", False) else None
    state = init_state(params, fed_cfg.n_clients, fed_cfg, r_run,
                       attacker=att)
    if telemetry is not None:
        telemetry.bind_engine("sync")
        if telemetry.counters:
            state = state._replace(
                tele=obs_counters.init_column("sync", fed_cfg))
    round_fn = make_round(model, fed_cfg, data_attack=data_attack,
                          update_attack=update_attack, malicious=malicious,
                          faults=faults)
    K = fed_cfg.n_clients

    if driver == "python":
        round_jit = jax.jit(round_fn)
        history = []
        for t in range(1, n_rounds + 1):
            batch = dict(data_fn(t, jax.random.fold_in(rng, t)))
            if fed_cfg.avail_prob < 1.0:
                # always feed avail (ones at t=1) so every round runs the
                # same compiled program as the scan body — bit-for-bit
                a = (jax.random.uniform(jax.random.fold_in(rng, 10_000 + t),
                                        (K,))
                     < fed_cfg.avail_prob).astype(jnp.float32)
                a = a.at[0].set(1.0)               # never an empty round
                batch["avail"] = a if t > 1 else jnp.ones((K,), jnp.float32)
            w0 = telemetry.now_us() if telemetry is not None else 0.0
            state, metrics = round_jit(state, batch)
            row = {k: jax.device_get(v) for k, v in metrics.items()}
            if eval_fn is not None:
                row.update(jax.device_get(eval_fn(state.params)))
            row["round"] = t
            if telemetry is not None:
                # device_get above synced, so the window is a real
                # per-round host measurement under this driver —
                # measured=True emits it as a real (non-attributed)
                # round span alongside the attributed phase split
                telemetry.observe_rows([row], w0,
                                       telemetry.now_us() - w0,
                                       measured=True)
            history.append(row)
        return state, history
    if driver != "scan":
        raise ValueError(driver)

    def body(st, xs):
        t, batch = xs
        if fed_cfg.avail_prob < 1.0:
            a = (jax.random.uniform(jax.random.fold_in(rng, 10_000 + t),
                                    (K,))
                 < fed_cfg.avail_prob).astype(jnp.float32)
            a = a.at[0].set(1.0)                   # never an empty round
            batch = dict(batch)
            batch["avail"] = jnp.where(t > 1, a, jnp.ones((K,), jnp.float32))
        st, metrics = round_fn(st, batch)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(st.params)}
        return st, metrics

    return scan_driver.run_chunked(
        body, state, lambda t: data_fn(t, jax.random.fold_in(rng, t)),
        n_rounds, chunk_steps=chunk_rounds, t0=1, index_key="round",
        telemetry=telemetry)
