"""PodEngine: one FedFiTS round as a single SPMD program for the big
architectures (DESIGN.md §2 "FL-on-pod").

Mapping:
  * the C client groups partition the global batch along the mesh "data"
    axis; per-client losses come from a (C, B/C, S) reshape of the
    per-token loss — no cross-client collectives in the local phase;
  * E local epochs = E-step gradient accumulation per client group
    (first-order-equivalent to local SGD at pod scale; see DESIGN.md);
  * slot-internal aggregation = the trust/team/size-weighted sum
    sum_c w_c * grad_c, realised as ONE weighted backward pass (psum over
    "data"); cross-slot aggregation = the same reduction's "pod" axis leg;
  * fitness (theta/score/threshold/team/trust/slot counters) are O(C)
    scalars carried in PodState — the entire round jits into one program.

``robust='per_client'`` materialises per-client grads (vmap) and runs the
coordinate-robust aggregators through the two-pass Pallas engine
(kernels/robust_pipeline.py): each (C, n_leaf) grad leaf is streamed
twice instead of sorted ~4 times, leaf-wise (segment-table grid — no
(C, N_params) flatten concatenate).  With ``agg_mesh`` the flattened
param axis additionally shards over the mesh
(aggregation.aggregate_sharded): every device streams only its shard in
both passes and only the (C,) cosine partials (+ Krum's Gram matrix)
cross devices in one psum, so per-device HBM traffic drops by the mesh
size instead of replicating the whole grad matrix; the grads are
constrained to the ``client_flat_specs`` layout before the shard_map
boundary, so the vmap'd backward emits them in place — no reshard
collective at the boundary.  Memory-feasible for <=20B models (see
DESIGN.md §2) and used by the smoke tests.

Multi-round training runs through ``pod.run`` on the shared chunked-scan
driver (core/driver.py): donated carry, on-device metric history, and
sharding-aware double-buffered batch prefetch — the same subsystem that
drives ``fedfits.run`` (wired end-to-end by ``launch/train.py``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import codecs as comm_codecs, error_feedback
from repro.core import aggregation, driver as scan_driver, fitness, \
    selection, slots
from repro.models import transformer
from repro.optim import optimizers


class PodFedState(NamedTuple):
    team: jnp.ndarray          # (C,)
    trust: jnp.ndarray         # (C,)
    alpha: jnp.ndarray
    slot: slots.SlotState
    h: jnp.ndarray
    rng: jnp.ndarray
    round: jnp.ndarray
    cum_selected: jnp.ndarray
    ef: Any = None             # per-client-group EF residual (compress on)


class PodState(NamedTuple):
    params: Any
    opt_state: Any
    fed: PodFedState
    step: jnp.ndarray


def init_pod_state(params, opt_init, C, fed_cfg, rng):
    ef = None
    if getattr(fed_cfg, "compress", "none") != "none" \
            and fed_cfg.error_feedback:
        # (C, ...) residual matching the per-client grad tree of the
        # robust='per_client' path — rides the ScanDriver donated carry
        ef = jax.tree_util.tree_map(
            lambda p: jnp.zeros((C,) + p.shape, p.dtype), params)
    return PodState(
        params=params,
        opt_state=opt_init(params),
        fed=PodFedState(
            team=jnp.ones((C,), jnp.float32),
            trust=jnp.full((C,), 0.5, jnp.float32),
            alpha=jnp.float32(fed_cfg.alpha),
            slot=slots.init_slot_state(),
            h=jnp.array(True),
            rng=rng,
            round=jnp.int32(1),
            cum_selected=jnp.zeros((C,), jnp.float32),
            ef=ef,
        ),
        step=jnp.int32(0),
    )


def per_client_metrics(params, cfg, batch, C):
    """Per-client (loss, acc) from one forward. batch tokens: (GB, S)."""
    hidden, _, aux = transformer.forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        image_embeds=batch.get("image_embeds"), collect_logits=False)
    GB, S, _ = hidden.shape
    targets = batch["targets"]
    chunk = cfg.loss_chunk or S
    chunk = min(chunk, S)
    n = S // chunk

    def body(carry, xs):
        hc, tc = xs                                  # (GB, chunk, d), (GB, chunk)
        logits = transformer.lm_head(params, cfg, hc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], -1)[..., 0]
        correct = (jnp.argmax(logits, -1) == tc).astype(jnp.float32)
        ls, cs = carry
        return (ls + (logz - gold).sum(1), cs + correct.sum(1)), None

    h = hidden[:, : n * chunk].reshape(GB, n, chunk, -1).transpose(1, 0, 2, 3)
    t = targets[:, : n * chunk].reshape(GB, n, chunk).transpose(1, 0, 2)
    (loss_tok, acc_tok), _ = jax.lax.scan(
        body, (jnp.zeros((GB,), jnp.float32), jnp.zeros((GB,), jnp.float32)),
        (h, t), unroll=n if cfg.scan_unroll else 1)
    denom = float(n * chunk)
    loss_c = loss_tok.reshape(C, GB // C).mean(1) / denom
    acc_c = acc_tok.reshape(C, GB // C).mean(1) / denom
    return loss_c, acc_c, aux


def make_train_step(model_cfg, fed_cfg, train_cfg, *, robust=None,
                    eval_frac=4, zero1_shardings=None, agg_mesh=None,
                    agg_axes=None):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {tokens (GB, S), targets (GB, S), [embeds/image_embeds]}.
    GB % C == 0; client c owns rows [c*GB/C, (c+1)*GB/C).

    zero1_shardings: optional (compute_sh, master_sh) NamedSharding trees.
    When given, the step runs ZeRO-1: forward/backward on bf16 TP-sharded
    data-replicated weights (one all-gather per step over "data"), grads
    reduce-scattered back to the fully-sharded fp32 master + optimizer
    state. Baseline (None) keeps fp32 FSDPxTP weights in the matmuls and
    lets GSPMD pick the collectives.

    agg_mesh / agg_axes: with robust='per_client', shard the robust
    aggregation's flattened param axis over these mesh axes (default:
    every axis but "pod") via aggregation.aggregate_sharded — both fused
    passes then stream shard-locally instead of replicating the whole
    (C, N_params) grad matrix on every device.
    """
    C = fed_cfg.n_clients
    opt_init, opt_update = optimizers.make_optimizer(train_cfg)
    codec = comm_codecs.make_codec(fed_cfg)
    if codec is not None and robust != "per_client":
        raise ValueError(
            "FedConfig.compress needs robust='per_client': the weighted-"
            "backward path fuses aggregation into the backward pass, so "
            "no per-client update ever crosses a client->server boundary")

    def weighted_loss(params, batch, weights):
        loss_c, acc_c, aux = per_client_metrics(params, model_cfg, batch, C)
        total = jnp.sum(weights * loss_c) + aux
        return total, (loss_c, acc_c)

    def eval_slice(batch):
        """Held-out-ish slice: last 1/eval_frac of each client's rows."""
        def cut(x):
            if x is None or x.ndim < 2:
                return x
            GB = x.shape[0]
            bc = GB // C
            e = max(1, bc // eval_frac)
            xc = x.reshape(C, bc, *x.shape[1:])[:, -e:]
            return xc.reshape(C * e, *x.shape[1:])

        return {k: cut(v) for k, v in batch.items() if v is not None}

    def train_step(state: PodState, batch):
        fed = state.fed
        rng, r_sel = jax.random.split(fed.rng)
        t = fed.round
        new_ef = fed.ef
        bytes_up_pc = None

        # ---- round weights: team * trust * equal-size q (selection-aware) --
        w = fed.team * fed.trust
        w = w / jnp.maximum(w.sum(), 1e-12)

        if zero1_shardings is not None:
            # ZeRO-1: bf16 compute copy, replicated over "data"
            compute_sh, master_sh = zero1_shardings
            cparams = jax.lax.with_sharding_constraint(
                jax.tree_util.tree_map(
                    lambda p: p.astype(jnp.bfloat16), state.params),
                compute_sh)

            (_, (loss_c, acc_c)), grads = jax.value_and_grad(
                weighted_loss, has_aux=True)(cparams, batch, w)
            # reduce-scatter grads back onto the master layout
            grads = jax.lax.with_sharding_constraint(
                jax.tree_util.tree_map(lambda g: g.astype(jnp.float32),
                                       grads), master_sh)
        elif robust == "per_client":
            def client_grad(c):
                GB = batch["tokens"].shape[0] if batch.get("tokens") is not None \
                    else batch["embeds"].shape[0]
                bc = GB // C

                def one_loss(p):
                    sub = {k: (jax.lax.dynamic_slice_in_dim(v, c * bc, bc)
                               if (v is not None and v.ndim >= 1
                                   and v.shape[0] == GB) else v)
                           for k, v in batch.items()}
                    l, m = transformer.loss_fn(p, model_cfg, sub)
                    return l, m

                (l, m), g = jax.value_and_grad(one_loss, has_aux=True)(
                    state.params)
                return g, l, m["acc"]

            grads_c, loss_c, acc_c = jax.vmap(client_grad)(jnp.arange(C))
            enc = None
            if codec is not None:
                # client->server boundary: EF inject -> encode; only the
                # wire format reaches the server-side aggregation below
                enc, dec, new_ef = error_feedback.compress(
                    codec, grads_c, fed.ef,
                    rng=jax.random.fold_in(rng, 7) if codec.stochastic
                    else None)
                bytes_up_pc = comm_codecs.wire_bytes_per_client(enc)
                grads_c = dec
            from repro.comm.kernels import comm_codecs as dq
            if enc is not None and dq.should_fuse(codec, fed_cfg, grads_c):
                if agg_mesh is not None:
                    grads = dq.fused_dequant_aggregate_sharded(
                        enc, w, fed.team, fed_cfg, agg_mesh, like=grads_c,
                        axes=agg_axes)
                else:
                    grads = dq.fused_dequant_aggregate_tree(
                        enc, w, fed.team, fed_cfg, like=grads_c,
                        blk=getattr(fed_cfg, "agg_blk", None))
            elif agg_mesh is not None and getattr(fed_cfg, "fused_agg",
                                                  True):
                grads = aggregation.aggregate_sharded(
                    grads_c, w, fed.team, fed_cfg, agg_mesh, axes=agg_axes)
            else:
                grads = aggregation.aggregate(grads_c, w, fed.team, fed_cfg)
        else:
            (_, (loss_c, acc_c)), grads = jax.value_and_grad(
                weighted_loss, has_aux=True)(state.params, batch, w)

        if train_cfg.grad_clip:
            grads, gnorm = optimizers.clip_by_global_norm(
                grads, train_cfg.grad_clip)
        else:
            gnorm = optimizers.global_norm(grads)

        updates, new_opt = opt_update(grads, state.opt_state, state.params)
        new_params = optimizers.apply_updates(state.params, updates)

        # ---- fitness: GL/GA pre-update (have it), LL/LA post-update ------
        ev = eval_slice(batch)
        if zero1_shardings is not None:
            eval_params = jax.lax.with_sharding_constraint(
                jax.tree_util.tree_map(
                    lambda p: p.astype(jnp.bfloat16), new_params),
                zero1_shardings[0])
        else:
            eval_params = new_params
        ll_c, la_c, _ = per_client_metrics(eval_params, model_cfg, ev, C)
        # LM "accuracy" for Eq.(1): bounded (0,1] proxy exp(-loss) blended
        # with token accuracy (DESIGN.md §2 table)
        ga = 0.5 * (jnp.exp(-loss_c) + acc_c)
        la = 0.5 * (jnp.exp(-ll_c) + la_c)
        th = jnp.where(t == 1, jnp.zeros((C,)),
                       fitness.theta(loss_c, ga, ll_c, la))
        q = jnp.full((C,), 1.0 / C)                 # equal data shards on pod
        alpha = jnp.where(jnp.array(fed_cfg.dynamic_alpha),
                          fitness.dynamic_alpha(q, th),
                          jnp.float32(fed_cfg.alpha))
        scores = fitness.score(q, th, alpha)

        avail = jnp.ones((C,), jnp.float32)
        new_team = selection.fedfits_select(
            scores, fed_cfg.beta, avail, r_sel,
            floor_prob=fed_cfg.participation_floor,
            explore_eps=fed_cfg.explore_eps)
        new_team = jnp.where(t == 1, avail, new_team)
        team = jnp.where(fed.h, new_team, fed.team)

        theta_team = fitness.team_theta(th, team)
        new_slot, h_next = slots.update(fed.slot, theta_team, t,
                                        fed_cfg.msl, fed_cfg.pft,
                                        adaptive=True)
        new_trust = aggregation.update_trust(fed.trust, scores, team,
                                             fed_cfg.trust_decay)

        new_state = PodState(
            params=new_params, opt_state=new_opt,
            fed=PodFedState(team=team, trust=new_trust, alpha=alpha,
                            slot=new_slot, h=h_next, rng=rng, round=t + 1,
                            cum_selected=fed.cum_selected + team,
                            ef=new_ef),
            step=state.step + 1)
        metrics = {
            "loss": jnp.sum(w * loss_c), "acc": jnp.sum(w * acc_c),
            "grad_norm": gnorm, "theta_team": theta_team,
            "team_size": team.sum(), "alpha": alpha,
        }
        if bytes_up_pc is not None:
            # measured uplink bytes this round (encoded wire sizes)
            metrics["comm_bytes_up"] = jnp.float32(bytes_up_pc * C)
        return new_state, metrics

    return train_step


def run(state, train_step, batch_fn, n_rounds, *, driver="scan",
        chunk_rounds=8, batch_sharding=None, t0=0, on_chunk=None,
        telemetry=None):
    """Multi-round PodEngine training through the shared chunked-scan
    driver (core/driver.py) — the same subsystem that drives
    ``fedfits.run``.

    ``train_step`` is an (unjitted) step from ``make_train_step``;
    ``batch_fn(step)`` a host callable returning one batch dict.

    driver="scan" (default): ``chunk_rounds`` steps per ``lax.scan``
    chunk with the metric history on device (ONE device_get per chunk),
    the carry DONATED (params/opt-state update in place), and chunk k+1's
    batches double-buffer-staged while chunk k computes.  With
    ``batch_sharding`` (a NamedSharding tree matching one batch — e.g.
    ``launch.inputs.batch_shardings``) the staging ``device_put``s each
    chunk's batches directly onto their pod shards (sharding-aware
    prefetch) instead of the default device.

    driver="python": the original per-round jitted loop, kept for parity
    testing — the scan history is bit-for-bit equal to it.

    PRNG footgun: the donated carry aliases the arrays ``state`` was
    built from, including the key stored in ``PodFedState.rng`` — the
    first chunk deletes those buffers, so ``batch_fn`` must sample from a
    COPY of the key taken before this call (see launch/train.py).

    Returns (final_state, history rows keyed by "step").
    ``on_chunk(state, rows)`` fires after each chunk (logging /
    checkpoint hook); the python driver fires it per round.

    ``telemetry`` (repro.obs.Telemetry) observes the drained rows and
    driver-level trace spans; the pod step publishes its existing
    metrics, so no extra carry column is attached here."""
    if telemetry is not None:
        telemetry.bind_engine("sync")

    def body(st, xs):
        _, batch = xs
        return train_step(st, batch)

    if driver == "python":
        step_jit = jax.jit(train_step, donate_argnums=(0,))
        put_sharding = batch_sharding
        history = []
        for t in range(t0, t0 + n_rounds):
            batch = dict(batch_fn(t))
            if put_sharding is not None:
                batch = jax.device_put(batch, put_sharding)
            w0 = telemetry.now_us() if telemetry is not None else 0.0
            state, metrics = step_jit(state, batch)
            row = {k: jax.device_get(v) for k, v in metrics.items()}
            row["step"] = t
            if telemetry is not None:
                telemetry.observe_rows([row], w0, telemetry.now_us() - w0)
            if on_chunk is not None:
                on_chunk(state, [row])
            history.append(row)
        return state, history
    if driver != "scan":
        raise ValueError(driver)

    return scan_driver.run_chunked(
        body, state, batch_fn, n_rounds, chunk_steps=chunk_rounds, t0=t0,
        batch_sharding=batch_sharding, index_key="step", on_chunk=on_chunk,
        telemetry=telemetry)
