"""System-heterogeneity fault injection for the round loop (ROADMAP
item 5 / scenario engine): stragglers, mid-round dropout, partial work.

All faults are sampled INSIDE ``make_round`` from keys folded off the
round's own rng streams, so they live entirely in the scan carry — the
chunked scan driver and the per-round python loop stay bit-for-bit
equal with fault injection on, and an inactive ``FaultConfig()`` is
bit-identical to ``faults=None`` (every sampler is skipped, not fed
zero probabilities).

Fault semantics (and how they thread into the existing machinery):

  stragglers    per-client exponential delay vs a round deadline; a late
                client simply never arrives -> its availability bit
                drops, which composes with the whole availability path:
                selection masks, fitness masks, and ``stale_weight``
                catch-up (a slot-team member that straggles re-enters at
                stale weight next round, exactly like any other
                unavailability).  Delay SCALES are heterogeneous per
                client: the last ``ceil(straggler_frac * K)`` clients
                are chronic stragglers with mean ``straggler_delay``;
                the rest draw at ``base_delay`` (malicious clients are
                conventionally the FIRST rows, so the two populations
                stay disjoint by default).
  dropout       mid-round loss: a SELECTED client computes its update
                (and is therefore still billed its client-round and its
                measured uplink bytes — the loss is at the server side
                of the wire) but the update never enters the aggregate.
                Dropped slot-team members are NOT stale catch-up
                contributors (stale covers clients that never arrived);
                their update is simply lost.
  partial work  heterogeneous effective local epochs: client k runs
                ceil(frac_k * E) of the configured E epochs, frac_k ~
                U[partial_min_frac, 1] per round.  The vmapped client
                step still computes all E epochs (SPMD-uniform, same as
                the availability simulation) but parameter updates stop
                after the client's effective count.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class FaultConfig:
    dropout_prob: float = 0.0        # P(selected client's update is lost)
    straggler_frac: float = 0.0      # fraction of chronically slow clients
    straggler_delay: float = 2.0     # mean delay of slow clients
    base_delay: float = 0.0          # mean delay of everyone else (0 = never late)
    deadline: float = 1.0            # round deadline the delay races
    partial_min_frac: float = 1.0    # effective epochs ~ ceil(U[f,1] * E)

    @property
    def stragglers_active(self) -> bool:
        return (self.straggler_frac > 0.0 and self.straggler_delay > 0.0) \
            or self.base_delay > 0.0

    @property
    def dropout_active(self) -> bool:
        return self.dropout_prob > 0.0

    @property
    def partial_active(self) -> bool:
        return self.partial_min_frac < 1.0

    @property
    def active(self) -> bool:
        return self.stragglers_active or self.dropout_active \
            or self.partial_active


def delay_scales(fl: FaultConfig, n_clients: int, *, rows: str = "tail"):
    """(K,) per-client mean-delay scales: the chronic stragglers get
    ``straggler_delay``, everyone else ``base_delay``.  ``rows`` places
    the chronic set at the population ``"tail"`` (default — malicious
    clients are conventionally the FIRST rows, so the populations stay
    disjoint) or ``"head"`` (the late-poison scenarios, where the
    colluders ARE the stragglers and their updates arrive at stale
    weight through the async buffer)."""
    k = n_clients
    if fl.straggler_frac > 0:
        n_slow = min(max(math.ceil(fl.straggler_frac * k - 1e-9), 1), k)
    else:
        n_slow = 0
    if rows == "head":
        is_slow = (jnp.arange(k) < n_slow).astype(jnp.float32)
    elif rows == "tail":
        is_slow = (jnp.arange(k) >= (k - n_slow)).astype(jnp.float32)
    else:
        raise ValueError(f"rows must be 'head' or 'tail', got {rows!r}")
    return fl.base_delay + (fl.straggler_delay - fl.base_delay) * is_slow


def sample_delays(scale, rng):
    """Exponential arrival delays with per-client mean ``scale`` (same
    shape).  A zero scale is an always-instant client."""
    u = jax.random.uniform(rng, scale.shape, minval=1e-7, maxval=1.0)
    return scale * (-jnp.log(u))


def sample_arrivals(fl: FaultConfig, rng, n_clients: int):
    """(K,) 0/1 arrival mask: client k arrives iff its exponential delay
    (mean = its per-client scale) beats the deadline."""
    delay = sample_delays(delay_scales(fl, n_clients), rng)
    return (delay <= fl.deadline).astype(jnp.float32)


def sample_dropout(fl: FaultConfig, rng, team):
    """(K,) 0/1 mask of SELECTED clients whose update is lost mid-round."""
    u = jax.random.uniform(rng, team.shape)
    return (u < fl.dropout_prob).astype(jnp.float32) * team


def sample_epochs(fl: FaultConfig, rng, n_clients: int, local_epochs: int):
    """(K,) i32 effective local-epoch counts in [1, E]."""
    frac = jax.random.uniform(
        rng, (n_clients,), minval=fl.partial_min_frac, maxval=1.0)
    eff = jnp.ceil(frac * local_epochs).astype(jnp.int32)
    return jnp.clip(eff, 1, local_epochs)
