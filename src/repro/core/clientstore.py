"""Population-scale client-state store (ROADMAP item 1).

Everything before this PR assumed the cohort IS the population: fitness,
trust, gate-trust, staleness and EF residuals lived in dense (K,) arrays
inside ``FedState`` sized by the per-round cohort.  A real cross-device
deployment (FedSelect-ME's multi-edge regime) registers MILLIONS of
clients of which a few dozen are sampled per round.  ``ClientStore``
decouples the two sizes:

  * one pytree of (M,) per-client columns — fitness, trust, gate_trust,
    staleness, failures, cum_selected — plus optional (M, ...) EF
    residual handles, sized by the REGISTERED population M;
  * the per-round cohort is a (C,) int32 index vector into the store:
    ``gather`` pulls the sampled rows into the round, ``scatter_*``
    helpers write the round's outcomes back (EWMA updates, failure
    decay, staleness bumps) — all O(C) scatters against O(M) state;
  * cohort sampling is O(M) Gumbel-top-d over the store's selection
    priority (``kernels/population_select.py``: blocked Pallas /
    segmented-XLA reduction — no full M log M argsort), so selection at
    M = 1e6 stays a streaming pass (``bench_kernels``'s
    ``population_select/*`` entries record the wall vs the dense argsort
    baseline);
  * on a mesh the (M,) columns shard over the combined data x model axes
    (``sharding.specs.client_store_specs``); gather/scatter become the
    only cross-shard traffic of the selection path.

The synchronous SimEngine (core/fedfits.py) now carries a ClientStore
with M == K (population == cohort — the old behavior as a special
case); the buffered-async engine (core/async_engine.py) runs M >> C.

Chronic-failure routing: every abandoned delivery or guard rejection
(NaN/Inf/absurd-norm update) bumps ``failures`` and decays ``trust``
multiplicatively, so the Gumbel-top-d priority of a flaky or hostile
client shrinks and the scheduler routes around it — the
``graceful degradation`` contract of the async round engine.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

_EPS = 1e-12


class ClientStore(NamedTuple):
    """Per-client persistent state, one row per REGISTERED client (M,)."""
    fitness: jnp.ndarray       # (M,) last fitness score (selection prior)
    trust: jnp.ndarray         # (M,) score-driven EWMA trust
    gate_trust: jnp.ndarray    # (M,) cosine-gate / guard rejection EWMA
    staleness: jnp.ndarray     # (M,) i32 rounds since last delivery
    failures: jnp.ndarray      # (M,) abandoned/rejected delivery count
    cum_selected: jnp.ndarray  # (M,) times sampled into a cohort
    ef: Any = None             # (M, ...) EF residual handles (compress on)

    @property
    def population(self) -> int:
        return self.fitness.shape[0]


def init_store(population: int, *, params=None, fed_cfg=None,
               fitness_prior: float = 0.5) -> ClientStore:
    """Fresh store for ``population`` registered clients.  EF residual
    handles are allocated only when the fed config compresses the uplink
    with error feedback (they are (M, ...)-dense here — at true
    million-client scale they would be slot handles into a cohort-sized
    pool, which is why they live behind the store boundary)."""
    m = int(population)
    ef = None
    if params is not None and fed_cfg is not None \
            and getattr(fed_cfg, "compress", "none") != "none" \
            and getattr(fed_cfg, "error_feedback", False):
        ef = jax.tree_util.tree_map(
            lambda p: jnp.zeros((m,) + p.shape, p.dtype), params)
    return ClientStore(
        fitness=jnp.full((m,), fitness_prior, jnp.float32),
        trust=jnp.full((m,), 0.5, jnp.float32),
        gate_trust=jnp.ones((m,), jnp.float32),
        staleness=jnp.zeros((m,), jnp.int32),
        failures=jnp.zeros((m,), jnp.float32),
        cum_selected=jnp.zeros((m,), jnp.float32),
        ef=ef,
    )


def gather(store: ClientStore, idx) -> ClientStore:
    """Pull the cohort rows (C,) out of the (M,) store columns."""
    return jax.tree_util.tree_map(lambda a: a[idx], store)


def selection_priority(store: ClientStore) -> jnp.ndarray:
    """(M,) sampling weight for the Gumbel-top-d cohort draw: fitness
    prior x both trust tracks.  Chronically flaky clients (decayed trust)
    and repeatedly-gated clients (low gate_trust) sink; the additive
    floor keeps every registered client reachable (no starvation — the
    A4 participation-floor analogue at population scale)."""
    pri = (store.fitness + 0.05) * store.trust * store.gate_trust
    return jnp.maximum(pri, _EPS)


def select_cohort(store: ClientStore, d: int, rng, *, method="segmented",
                  blk: int = 4096) -> jnp.ndarray:
    """Sample a without-replacement cohort of ``d`` clients with
    probability proportional to ``selection_priority`` via Gumbel-top-d
    (Efraimidis-Spirakis), O(M): see selection.population_cohort and
    kernels/population_select.py."""
    from repro.core import selection
    return selection.population_cohort(
        selection_priority(store), d, rng, method=method, blk=blk)


# ----------------------------------------------------------------------
# round-outcome scatters (all O(C) against the (M,) columns)
# ----------------------------------------------------------------------
def record_selection(store: ClientStore, idx) -> ClientStore:
    """cum_selected bump for the sampled cohort."""
    return store._replace(
        cum_selected=store.cum_selected.at[idx].add(1.0))


def record_fitness(store: ClientStore, idx, scores, decay: float
                   ) -> ClientStore:
    """EWMA the cohort's freshly-computed fitness scores into the store
    (computed at COMPUTE time — a late delivery does not re-evaluate)."""
    old = store.fitness[idx]
    new = decay * old + (1.0 - decay) * scores
    return store._replace(fitness=store.fitness.at[idx].set(new))


def record_deliveries(store: ClientStore, owners, delivered_mask
                      ) -> ClientStore:
    """Staleness: +1 for everyone, reset to 0 for clients whose update
    entered this round's aggregation buffer (on time or via retry).
    ``owners`` (R,) population indices with a 0/1 ``delivered_mask``;
    masked-off rows scatter out of range (dropped)."""
    m = store.population
    tgt = jnp.where(delivered_mask > 0, owners, m)     # m = out of range
    stale = (store.staleness + 1).at[tgt].set(0, mode="drop")
    return store._replace(staleness=stale)


def record_failures(store: ClientStore, owners, failed_mask, *,
                    trust_penalty: float = 0.7) -> ClientStore:
    """Chronic-failure decay: each failed delivery (deadline exhausted,
    buffer overflow, or guard rejection) bumps ``failures`` and decays
    ``trust`` multiplicatively — repeated failure routes the scheduler
    around the client (its Gumbel-top-d priority shrinks).  Duplicate
    owners in one round compound via the product."""
    m = store.population
    tgt = jnp.where(failed_mask > 0, owners, m)
    fails = store.failures.at[tgt].add(1.0, mode="drop")
    pen = jnp.ones((m,), jnp.float32).at[tgt].multiply(
        trust_penalty, mode="drop")
    return store._replace(failures=fails, trust=store.trust * pen)


def record_gate_trust(store: ClientStore, owners, part_mask, gated_mask,
                      decay: float) -> ClientStore:
    """Cosine-gate EWMA at population scale: participating owners decay
    toward (1 - gated); everyone else holds.  Mirrors the in-round EWMA
    of the synchronous engine.  With duplicate owners (a client's fresh
    and buffered update in one round) the last scatter wins — an
    acceptable tie-break for an EWMA."""
    m = store.population
    tgt = jnp.where(part_mask > 0, owners, m)
    old = store.gate_trust[jnp.clip(owners, 0, m - 1)]
    new = decay * old + (1.0 - decay) * (1.0 - gated_mask)
    gt = store.gate_trust.at[tgt].set(
        jnp.where(part_mask > 0, new, 0.0), mode="drop")
    return store._replace(gate_trust=gt)
