"""Buffered-async round engine (ROADMAP item 1): population-scale
client scheduling with deadline/timeout semantics and graceful
degradation under client failure.

The synchronous SimEngine (core/fedfits.py) assumes the cohort IS the
population and every contributor answers inside the round.  This engine
models the cross-device regime (FedSelect-ME's multi-edge setting):

  population   M registered clients live in a sharded ClientStore
               (core/clientstore.py); each round samples a cohort of
               C = fed_cfg.n_clients rows by O(M) Gumbel-top-d over the
               store's fitness x trust priority (selection.
               population_cohort -> kernels/population_select.py) and
               gathers just those rows into the round.
  deadline     every cohort delivery races ``async_deadline`` with a
               heterogeneous exponential delay (core/faults.py: chronic
               stragglers at ``straggler_delay``, the rest at
               ``base_delay``).  On-time updates aggregate at full
               weight.
  buffer       a late update is NOT lost: it parks in a fixed-capacity
               DeliveryBuffer (B = C * async_max_retries rows) and
               retries on later rounds with CAPPED BACKOFF — the retry
               window of a row aged a is deadline * backoff^a, so each
               retry listens longer (FedBuff-style buffered async
               aggregation, generalizing the sync engine's
               ``stale_weight`` catch-up path).  When it finally lands
               it enters the aggregation at staleness-decayed weight
               n_k * trust * staleness_decay^a — fresh evidence
               dominates stale evidence, and the combination stays
               convex (``delivery_weights``).
  timeout      a row that exhausts ``async_max_retries`` (or arrives
               when the buffer is full) is ABANDONED: the work was done
               and is billed (billed-but-lost, exactly the PR-5 dropout
               semantics) but the bytes never help the model, and the
               client's failure count rises while its trust decays
               multiplicatively — the Gumbel-top-d priority shrinks and
               the scheduler routes around chronically flaky clients
               (graceful degradation).
  guard        every delivery (fresh or buffered) passes the
               aggregation-boundary guard (aggregation.sanitize_updates)
               — NaN/Inf or absurd-norm rows are rejected with a trust
               penalty instead of poisoning the global model.

Every draw (cohort sample, local-training keys, delivery delays) folds
off the round carry's rng, so the chunked ``lax.scan`` driver and the
per-round jitted python loop are bit-for-bit equal with the buffer,
retry/backoff, and fault injection all active (tests/test_async_engine).

Compression is deliberately NOT supported here: EF residuals are
per-client persistent state, and at M >> C they must live behind the
ClientStore boundary as (M, ...) columns (that is exactly why the sync
engine's ``ef`` moved into the store this PR); wiring the codec through
gather/scatter is future work, so ``compress != none`` raises.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comm import codecs as comm_codecs
from repro.core import aggregation, clientstore, driver as scan_driver, \
    fairness, faults as faults_mod, fitness
from repro.obs import counters as obs_counters
from repro.obs.trace import annotate as obs_annotate

_EPS = 1e-12


class DeliveryBuffer(NamedTuple):
    """Fixed-capacity parking lot for late deliveries (B rows)."""
    upd: Any                  # (B, ...) update rows (zeros when inactive)
    owner: jnp.ndarray        # (B,) i32 population row of the delivery
    n_k: jnp.ndarray          # (B,) f32 owner's example count (weight)
    age: jnp.ndarray          # (B,) i32 rounds spent buffered (>= 1)
    remaining: jnp.ndarray    # (B,) f32 delay left past consumed windows
    active: jnp.ndarray       # (B,) 0/1 occupancy


class AsyncState(NamedTuple):
    params: Any
    clients: clientstore.ClientStore   # (M,) population columns
    buf: DeliveryBuffer
    rng: jnp.ndarray
    round: jnp.ndarray
    cost_client_rounds: jnp.ndarray
    cost_bytes_up: jnp.ndarray
    cost_bytes_down: jnp.ndarray
    attacker: Any = None      # stateful-attacker carry (None = stateless)
    tele: Any = None          # telemetry carry column (repro/obs/):
                              # {counter name: f32 array}; None = obs off

    # summarize()-compat read paths (match FedState's properties)
    @property
    def trust(self):
        return self.clients.trust

    @property
    def gate_trust(self):
        return self.clients.gate_trust

    @property
    def cum_selected(self):
        return self.clients.cum_selected


def buffer_capacity(fed_cfg) -> int:
    """B = C * max_retries: every cohort row can be late every round and
    nothing is evicted before its retries run out."""
    return max(fed_cfg.n_clients * fed_cfg.async_max_retries, 1)


def init_buffer(params, fed_cfg) -> DeliveryBuffer:
    b = buffer_capacity(fed_cfg)
    upd = jax.tree_util.tree_map(
        lambda p: jnp.zeros((b,) + p.shape, p.dtype), params)
    return DeliveryBuffer(
        upd=upd,
        owner=jnp.zeros((b,), jnp.int32),
        n_k=jnp.zeros((b,), jnp.float32),
        age=jnp.zeros((b,), jnp.int32),
        remaining=jnp.zeros((b,), jnp.float32),
        active=jnp.zeros((b,), jnp.float32),
    )


def init_async_state(params, fed_cfg, rng, *, attacker=None) -> AsyncState:
    m = fed_cfg.population or fed_cfg.n_clients
    att = attacker.init(m) if attacker is not None else None
    return AsyncState(
        params=params,
        clients=clientstore.init_store(m),
        buf=init_buffer(params, fed_cfg),
        rng=rng,
        round=jnp.int32(1),
        cost_client_rounds=jnp.float32(0.0),
        cost_bytes_up=jnp.float32(0.0),
        cost_bytes_down=jnp.float32(0.0),
        attacker=att,
    )


def delivery_weights(n_k, trust, mask, age, *, staleness_decay):
    """The normalized aggregation weights of one async round: raw weight
    n_k * trust * staleness_decay^age per masked-in delivery, normalized
    over the round's delivery set.  Always a convex combination (entries
    in [0, 1] summing to 1 — or all-zero for an empty round), which is
    the property tests' invariant; the round body feeds the SAME raw
    weights through ``aggregation.aggregate`` (whose ``normalize_weights``
    applies the identical normalization)."""
    w = n_k * trust * staleness_decay ** age.astype(jnp.float32)
    return aggregation.normalize_weights(w, mask)


def make_async_round(model, fed_cfg, pop_data, *, batch_size=32,
                     eval_batch=32, data_attack=None, update_attack=None,
                     malicious=None, faults=None, straggler_rows="tail"):
    """Builds the jittable buffered-async round body.

    ``pop_data``: population-stacked {x: (M, cap, ...), y, eval_x,
    eval_y, n} living on device (data/pipeline.py ``Federation.data``).
    Per-round cohort batches are sampled INSIDE the body from the carry
    rng, so the scan and python drivers see identical draws.
    """
    from repro.core import fedfits   # cycle-free: fedfits doesn't import us

    if getattr(fed_cfg, "compress", "none") != "none":
        # FedConfig.__post_init__ already rejects population>0 +
        # compress; this guards duck-typed / hand-rolled configs too.
        raise ValueError(
            f"compress={fed_cfg.compress!r}: the buffered-async engine "
            "is dense-uplink only (EF residual columns must live behind "
            "the ClientStore boundary before a codec can ride the retry "
            "buffer). Use the sync engine (fedfits.run) for compressed "
            "uplink, or compress='none' here.")
    client_update = fedfits.make_client_update(model, fed_cfg)
    m = fed_cfg.population or fed_cfg.n_clients
    c = fed_cfg.n_clients
    retries = int(fed_cfg.async_max_retries)
    deadline = float(fed_cfg.async_deadline)
    backoff = float(fed_cfg.async_backoff)
    sdecay = float(fed_cfg.staleness_decay)
    guard_on = getattr(fed_cfg, "update_guard", True)
    stateful_attack = getattr(update_attack, "stateful", False)
    mal = malicious if malicious is not None else jnp.zeros((m,), jnp.float32)
    fl = faults if faults is not None else faults_mod.FaultConfig()
    # per-POPULATION-row chronic-straggler delay scales, fixed per run
    scales_pop = faults_mod.delay_scales(fl, m, rows=straggler_rows) \
        if fl.stragglers_active else jnp.zeros((m,), jnp.float32)
    cap = pop_data["x"].shape[1]
    ecap = pop_data["eval_x"].shape[1]
    bsz = min(batch_size, cap)
    esz = min(eval_batch, ecap)

    def round_fn(state: AsyncState, _batch):
        rng, r_sel, r_cli, r_data, r_upd, r_delay = \
            jax.random.split(state.rng, 6)
        t = state.round
        store = state.clients
        buf = state.buf

        # ---- O(M) cohort sampling + O(C) gather ------------------------
        with obs_annotate("selection"):
            idx = clientstore.select_cohort(
                store, c, r_sel, method=fed_cfg.select_method)
            store = clientstore.record_selection(store, idx)
            rows = jax.tree_util.tree_map(lambda a: a[idx], pop_data)
        kb, ke = jax.random.split(jax.random.fold_in(r_data, 3))
        bi = jax.random.randint(kb, (c, bsz), 0, cap)
        ei = jax.random.randint(ke, (c, esz), 0, ecap)
        take = lambda arr, i: jax.vmap(lambda a, j: a[j])(arr, i)
        cdata = {"x": take(rows["x"], bi), "y": take(rows["y"], bi),
                 "eval_x": take(rows["eval_x"], ei),
                 "eval_y": take(rows["eval_y"], ei), "n": rows["n"]}
        cmal = mal[idx]
        if data_attack is not None:
            cdata = dict(cdata)
            cdata.update(data_attack(cdata, cmal, r_data))

        # ---- local training (vmapped cohort) ---------------------------
        eff = jnp.full((c,), fed_cfg.local_epochs, jnp.int32)
        keys = jax.random.split(r_cli, c)
        with obs_annotate("client_update"):
            locals_, (gl, ga, ll, la) = jax.vmap(
                client_update, in_axes=(None, 0, 0, 0))(state.params,
                                                        cdata, keys, eff)
        updates = jax.tree_util.tree_map(
            lambda w_k, w: w_k - w[None], locals_, state.params)
        att_carry = state.attacker
        if update_attack is not None:
            if stateful_attack:
                att_view = update_attack.gather(state.attacker, idx) \
                    if hasattr(update_attack, "gather") else state.attacker
                updates, att_carry = update_attack(
                    updates, cmal, r_upd, att_view)
            else:
                updates = update_attack(updates, cmal, r_upd)

        # ---- fitness at COMPUTE time (a late delivery does not
        # re-evaluate; its score was recorded when the work ran) ---------
        ones_c = jnp.ones((c,), jnp.float32)
        q = fitness.data_quality(cdata["n"], ones_c)
        th = jnp.where(t == 1, jnp.zeros((c,)),
                       fitness.theta(gl, ga, ll, la))
        alpha = jnp.where(
            jnp.array(fed_cfg.dynamic_alpha),
            fitness.dynamic_alpha(q, th, ones_c),
            jnp.float32(fed_cfg.alpha))
        scores = fitness.score(q, th, alpha)
        store = clientstore.record_fitness(store, idx, scores,
                                           fed_cfg.trust_decay)

        # ---- the delivery race -----------------------------------------
        with obs_annotate("delivery"):
            delay = faults_mod.sample_delays(
                scales_pop[idx], jax.random.fold_in(r_delay, 11)) \
                if fl.stragglers_active else jnp.zeros((c,), jnp.float32)
            on_time = (delay <= deadline).astype(jnp.float32)
            late = 1.0 - on_time

            # ---- buffer maturity: which parked rows land this round? ---
            # a row aged a listens for window = deadline * backoff^a
            # (capped backoff: a <= max_retries by construction); if its
            # residual delay fits, it is DUE and delivers at staleness-
            # decayed weight; if not and its retries are spent it is
            # ABANDONED (failure); otherwise it consumes the window and
            # ages one round.
            window = deadline * backoff ** buf.age.astype(jnp.float32)
            due = buf.active * (buf.remaining <= window).astype(jnp.float32)
            exhausted = buf.active * (1.0 - due) \
                * (buf.age >= retries).astype(jnp.float32)
            still = buf.active * (1.0 - due) * (1.0 - exhausted)

        # ---- staleness-weighted aggregation over fresh ∪ due -----------
        all_upd = jax.tree_util.tree_map(
            lambda u, b: jnp.concatenate([u, b], axis=0), updates, buf.upd)
        owners = jnp.concatenate([idx, buf.owner])
        owner_safe = jnp.clip(owners, 0, m - 1)
        age_all = jnp.concatenate(
            [jnp.zeros((c,), jnp.int32), buf.age])
        nk_all = jnp.concatenate([cdata["n"].astype(jnp.float32), buf.n_k])
        mask_pre = jnp.concatenate([on_time, due])
        w_raw = nk_all * store.trust[owner_safe] \
            * sdecay ** age_all.astype(jnp.float32)

        rejected = jnp.zeros_like(mask_pre)
        mask = mask_pre
        g_nonfinite = g_norm = jnp.float32(0.0)
        if guard_on:
            if state.tele is not None:
                # guard rejections split by kind — shares the guard's own
                # reductions (CSE), a pure readout
                nf, nr = aggregation.rejection_kinds(
                    all_upd, mask_pre, norm_mult=fed_cfg.guard_norm_mult)
                g_nonfinite, g_norm = nf.sum(), nr.sum()
            with obs_annotate("sanitize"):
                all_upd, mask, rejected = aggregation.sanitize_updates(
                    all_upd, mask_pre, norm_mult=fed_cfg.guard_norm_mult)
        with obs_annotate("aggregate"):
            agg = aggregation.aggregate(all_upd, w_raw, mask, fed_cfg)
        with obs_annotate("writeback"):
            new_params = jax.tree_util.tree_map(
                lambda p, u: p + u.astype(p.dtype), state.params, agg)

        # ---- cosine gate + trust bookkeeping ---------------------------
        cos = aggregation.cosine_to_ref(all_upd, agg)
        gated = ((cos < fed_cfg.cosine_outlier_thresh)
                 & (mask > 0)).astype(jnp.float32)
        bad = jnp.maximum(gated, rejected)
        if stateful_attack:
            # the attacker only observes its own cohort rows' outcome
            att_carry = update_attack.observe(
                att_carry,
                jnp.zeros((m,), jnp.float32).at[owner_safe].max(
                    bad * mask_pre))
        store = clientstore.record_gate_trust(
            store, owners, mask_pre, bad, fed_cfg.trust_decay)
        # aggregation-trust EWMA for the cohort (compute-time scores)
        old_tr = store.trust[idx]
        new_tr = fed_cfg.trust_decay * old_tr \
            + (1.0 - fed_cfg.trust_decay) * scores
        store = store._replace(trust=store.trust.at[idx].set(new_tr))
        store = clientstore.record_deliveries(
            store, owners, mask_pre * (1.0 - rejected))

        # ---- buffer update: free landed/abandoned rows, park the late -
        if retries > 0:
            rem_mid = jnp.where(still > 0, buf.remaining - window, 0.0)
            age_mid = jnp.where(still > 0, buf.age + 1, 0)
            free = 1.0 - still
            # j-th free slot, in slot order: free slots keep their index
            # as the sort key, occupied ones sort after every free one
            b = still.shape[0]
            slot_order = jnp.argsort(jnp.where(
                free > 0, jnp.arange(b), b + jnp.arange(b)))
            late_rank = (jnp.cumsum(late) - 1.0).astype(jnp.int32)
            n_free = free.sum()
            can_park = (late > 0) & (late_rank.astype(jnp.float32) < n_free)
            dest = jnp.where(
                can_park, slot_order[jnp.clip(late_rank, 0, b - 1)],
                b).astype(jnp.int32)               # b = out of range: drop
            new_buf = DeliveryBuffer(
                upd=jax.tree_util.tree_map(
                    lambda bl, u: bl.at[dest].set(
                        u.astype(bl.dtype), mode="drop"),
                    buf.upd, updates),
                owner=buf.owner.at[dest].set(idx, mode="drop"),
                n_k=buf.n_k.at[dest].set(
                    cdata["n"].astype(jnp.float32), mode="drop"),
                age=age_mid.at[dest].set(1, mode="drop"),
                remaining=rem_mid.at[dest].set(
                    delay - deadline, mode="drop"),
                active=still.at[dest].set(1.0, mode="drop"),
            )
            overflow = late * (1.0 - can_park.astype(jnp.float32))
        else:
            new_buf = buf                           # no retries: no buffer
            overflow = late

        # ---- chronic-failure routing -----------------------------------
        # abandoned retries, buffer overflow, and guard rejections all
        # count: failures bump + multiplicative trust decay shrink the
        # owner's selection priority, so the scheduler routes around it
        fail = jnp.maximum(jnp.concatenate([overflow, exhausted]), rejected)
        store = clientstore.record_failures(store, owners, fail)

        # ---- billing: once per COMPUTED round --------------------------
        # every cohort client trained and transmitted this round: C
        # client-rounds + C encoded-update uplinks + C model downlinks.
        # Retried deliveries are NOT re-billed when they land (the work
        # ran once), and abandoned/timed-out work stays billed — exactly
        # the PR-5 dropout billed-but-lost semantics.
        bytes_up_pc = comm_codecs.dense_bytes_per_client(updates)
        bytes_down_pc = comm_codecs.param_bytes(state.params)
        billed = jnp.float32(c)

        # ---- telemetry readout (repro/obs/) -----------------------------
        # pure readouts of values the round already produced; nothing
        # downstream reads them back, so on/off runs are bit-identical
        new_tele, obs_metrics = state.tele, {}
        if state.tele is not None:
            wm = w_raw * mask
            vals = {
                "gate/cosine_rejected": gated.sum(),
                "guard/nonfinite": g_nonfinite,
                "guard/norm": g_norm,
                "select/team_size": jnp.float32(c),
                "delivery/on_time": on_time.sum(),
                "delivery/late": late.sum(),
                "buffer/occupancy": new_buf.active.sum(),
                "buffer/parked": (late - overflow).sum(),
                "buffer/overflow": overflow.sum(),
                "buffer/exhausted": exhausted.sum(),
                "buffer/age_hist": obs_counters.age_histogram(
                    new_buf.age, new_buf.active, fed_cfg),
                "agg/fresh_mass": wm[:c].sum(),
                "agg/stale_mass": wm[c:].sum(),
                "cohort/trust_q": obs_counters.quantiles(new_tr),
                "cohort/gate_trust_q": obs_counters.quantiles(
                    store.gate_trust[idx]),
                "cohort/fitness_q": obs_counters.quantiles(scores),
                "wire/bytes_up": billed * bytes_up_pc,
                "wire/bytes_down": billed * bytes_down_pc,
            }
            new_tele = obs_counters.accumulate(state.tele, vals, "async")
            obs_metrics = obs_counters.metric_keys(vals)

        new_state = AsyncState(
            params=new_params, clients=store, buf=new_buf, rng=rng,
            round=t + 1,
            cost_client_rounds=state.cost_client_rounds + billed,
            cost_bytes_up=state.cost_bytes_up + billed * bytes_up_pc,
            cost_bytes_down=state.cost_bytes_down + billed * bytes_down_pc,
            attacker=att_carry, tele=new_tele)
        metrics = {
            "team_size": jnp.float32(c),
            "on_time_frac": on_time.mean(),
            "delivered": mask.sum(),
            "buffered": (late - overflow).sum(),
            "buf_fill": new_buf.active.sum(),
            "abandoned": exhausted.sum() + overflow.sum(),
            "guard_rejected": rejected.sum(),
            "gated_frac": gated.sum() / jnp.maximum(mask_pre.sum(), 1.0),
            "gate_trust": store.gate_trust,
            "score": scores, "alpha": alpha,
            "global_loss_mean": gl.mean(), "local_loss_mean": ll.mean(),
            **fairness.round_fairness(ga, ones_c, store.cum_selected),
            **obs_metrics,
        }
        return new_state, metrics

    return round_fn


def run_async(model, fed_cfg, pop_data, n_rounds, rng, *, eval_fn=None,
              batch_size=32, eval_batch=32, data_attack=None,
              update_attack=None, malicious=None, faults=None,
              straggler_rows="tail", driver="scan", chunk_rounds=4,
              telemetry=None):
    """Drive ``n_rounds`` buffered-async rounds; returns (state, history).

    Mirrors ``fedfits.run``: driver="scan" goes through the shared
    chunked-scan driver, driver="python" is the per-round jitted loop
    kept for bit-parity testing — both consume identical carry-rng
    streams, and the batch feed is empty (population data is closed
    over; every draw lives in the carry)."""
    r_init, r_run = jax.random.split(rng)
    params = model.init(r_init)
    att = update_attack if getattr(update_attack, "stateful", False) \
        else None
    state = init_async_state(params, fed_cfg, r_run, attacker=att)
    if telemetry is not None:
        telemetry.bind_engine("async")
        if telemetry.counters:
            state = state._replace(
                tele=obs_counters.init_column("async", fed_cfg))
    round_fn = make_async_round(
        model, fed_cfg, pop_data, batch_size=batch_size,
        eval_batch=eval_batch, data_attack=data_attack,
        update_attack=update_attack, malicious=malicious, faults=faults,
        straggler_rows=straggler_rows)

    if driver == "python":
        round_jit = jax.jit(round_fn)
        history = []
        for t in range(1, n_rounds + 1):
            w0 = telemetry.now_us() if telemetry is not None else 0.0
            state, metrics = round_jit(state, {})
            row = {k: jax.device_get(v) for k, v in metrics.items()}
            if eval_fn is not None:
                row.update(jax.device_get(eval_fn(state.params)))
            row["round"] = t
            if telemetry is not None:
                # device_get above synced, so the window is a real
                # per-round host measurement under this driver —
                # measured=True emits it as a real (non-attributed)
                # round span alongside the attributed phase split
                telemetry.observe_rows([row], w0,
                                       telemetry.now_us() - w0,
                                       measured=True)
            history.append(row)
        return state, history
    if driver != "scan":
        raise ValueError(driver)

    def body(st, xs):
        _t, batch = xs
        st, metrics = round_fn(st, batch)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(st.params)}
        return st, metrics

    return scan_driver.run_chunked(
        body, state, lambda t: {}, n_rounds, chunk_steps=chunk_rounds,
        t0=1, index_key="round", telemetry=telemetry)
