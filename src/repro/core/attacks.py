"""Poisoning attack models (paper §VI: data & model poisoning) for the
robustness experiments. Data attacks corrupt the client's batch; model
attacks corrupt the client's *update* before it reaches the server.

Two attacker tiers:

  static    sign_flip / gaussian_update / scale_attack / label_flip /
            backdoor_trigger — oblivious to the defense.
  adaptive  alie / min_max / min_sum / gate_aware — optimization-based
            attackers (Baruch et al. 2019; Shejwalkar & Houmansadr 2021)
            that read the HONEST updates' statistics (omniscient-attacker
            convention: malicious clients collude and see every honest
            update) and, for ``gate_aware``, the defense's own config
            (``cosine_outlier_thresh`` / ``trim_frac``) to craft updates
            sitting *just inside* the cosine gate and trim window.

All model attacks leave honest rows bit-identical and are deterministic
given their inputs (the adaptive ones take no rng at all), so the scan
and python round drivers stay bit-for-bit equal under attack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


# ---------------------------------------------------------------- data ----
def label_flip(labels, n_classes, malicious, *, mode="shift"):
    """Flip labels of malicious clients. labels: (K, B); malicious: (K,) 0/1.

    mode 'shift': y -> (y+1) % C (paper's label-flipping attack);
    mode 'target': everything -> class 0 (targeted).
    """
    if mode == "shift":
        flipped = jnp.mod(labels + 1, n_classes)
    else:
        flipped = jnp.zeros_like(labels)
    m = malicious.reshape((-1,) + (1,) * (labels.ndim - 1))
    return jnp.where(m > 0, flipped, labels)


def stamp_trigger(x, *, patch=3, value=1.0, hw_axes=None):
    """Stamp the backdoor trigger onto a batch of inputs, layout-aware.

    Image batches carry an explicit channel axis, so any (..., H, W, C)
    layout has ndim >= 4 once a batch axis is present — those get a
    ``patch x patch`` corner stamp on the (H, W) axes.  2-D/3-D batches
    ((B, D) or client-stacked (K, B, D) tabular/flattened inputs) get a
    FEATURE-PREFIX trigger instead: the first ``patch`` features set to
    ``value``.  Pass ``hw_axes`` (e.g. (-3, -2)) to pin the spatial axes
    explicitly when the heuristic is wrong (e.g. channel-less (B, H, W)).
    """
    if hw_axes is None:
        if x.ndim >= 4:
            hw_axes = (-3, -2)
        else:                                   # feature-prefix trigger
            return x.at[..., :patch].set(value)
    idx = [slice(None)] * x.ndim
    for ax in hw_axes:
        idx[ax % x.ndim] = slice(0, patch)
    return x.at[tuple(idx)].set(value)


def backdoor_trigger(images, labels, malicious, *, target=0, patch=3,
                     hw_axes=None):
    """Stamp the trigger + relabel to ``target`` on malicious clients'
    batches (backdoor / targeted poisoning).  Layout-aware via
    ``stamp_trigger``: NHWC image batches get the classic white corner
    patch; (K, B, D) tabular batches get the feature-prefix trigger
    (the old unconditional ``[..., :p, :p, :]`` stamp silently sliced
    the batch and feature axes of non-image inputs)."""
    trig = stamp_trigger(images, patch=patch, hw_axes=hw_axes)
    m_im = malicious.reshape((-1,) + (1,) * (images.ndim - 1))
    m_lb = malicious.reshape((-1,) + (1,) * (labels.ndim - 1))
    return (jnp.where(m_im > 0, trig, images),
            jnp.where(m_lb > 0, jnp.full_like(labels, target), labels))


def feature_noise(x, malicious, sigma, rng):
    """Gaussian feature corruption (tabular/image)."""
    noise = sigma * jax.random.normal(rng, x.shape, x.dtype)
    m = malicious.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(m > 0, x + noise, x)


# --------------------------------------------------------------- model ----
def sign_flip(updates, malicious, *, scale=1.0):
    """Byzantine sign-flip: u -> -scale * u for malicious clients."""
    def leaf(l):
        m = malicious.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
        return l * (1.0 - m) + (-scale) * l * m

    return jax.tree_util.tree_map(leaf, updates)


def gaussian_update(updates, malicious, sigma, rng):
    """Replace malicious updates with pure noise."""
    flat, treedef = jax.tree_util.tree_flatten(updates)
    keys = jax.random.split(rng, len(flat))

    out = []
    for l, k in zip(flat, keys):
        m = malicious.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
        noise = sigma * jax.random.normal(k, l.shape, l.dtype)
        out.append(l * (1.0 - m) + noise * m)
    return jax.tree_util.tree_unflatten(treedef, out)


def scale_attack(updates, malicious, gamma):
    """Model-replacement scaling: u -> gamma * u (boosted poisoning)."""
    def leaf(l):
        m = malicious.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
        return l * (1.0 + (gamma - 1.0) * m)

    return jax.tree_util.tree_map(leaf, updates)


# ---------------------------------------------- adaptive (optimization) ----
def _flatten_clients(updates):
    """(K, N) fp32 view of a (K, ...)-leaved pytree + reassembly info."""
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    k = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(k, -1).astype(jnp.float32) for l in leaves], axis=1)
    return flat, leaves, treedef


def _unflatten_clients(flat, leaves, treedef):
    out, o = [], 0
    for l in leaves:
        n = l[0].size
        out.append(flat[:, o:o + n].reshape(l.shape).astype(l.dtype))
        o += n
    return jax.tree_util.tree_unflatten(treedef, out)


def _honest_stats(flat, malicious):
    """Per-coordinate mean/std over the HONEST rows (mask-weighted)."""
    h = (1.0 - malicious).astype(jnp.float32)
    nh = jnp.maximum(h.sum(), 1.0)
    mu = (flat * h[:, None]).sum(0) / nh
    var = (h[:, None] * jnp.square(flat - mu[None])).sum(0) / nh
    return mu, jnp.sqrt(var), h, nh


def _replace_malicious(flat, malicious, crafted):
    poisoned = jnp.where(malicious[:, None] > 0, crafted[None], flat)
    return poisoned


def alie(updates, malicious, *, z=None):
    """A-Little-Is-Enough [Baruch et al. 2019]: every malicious client
    submits mu - z * sigma per coordinate, where (mu, sigma) are the
    honest per-coordinate statistics and z is the largest deviation that
    still hides inside the honest spread.  Default z is the ALIE
    prescription z = Phi^-1((n - m - s) / (n - m)) with s = floor(n/2+1)-m
    (the count of honest clients a coordinate-median defense needs to
    out-vote), clipped to [0, 3]."""
    flat, leaves, treedef = _flatten_clients(updates)
    mu, sd, _, _ = _honest_stats(flat, malicious)
    if z is None:
        n = jnp.float32(flat.shape[0])
        m = malicious.astype(jnp.float32).sum()
        s = jnp.floor(n / 2.0 + 1.0) - m
        phi = jnp.clip((n - m - s) / jnp.maximum(n - m, 1.0),
                       0.5, 1.0 - 1e-6)
        z = jnp.clip(jax.scipy.special.ndtri(phi), 0.0, 3.0)
    crafted = mu - z * sd
    return _unflatten_clients(_replace_malicious(flat, malicious, crafted),
                              leaves, treedef)


def _dev_direction(dev, mu, sd):
    if dev == "unit":
        return -mu / jnp.maximum(jnp.linalg.norm(mu), _EPS)
    if dev == "std":
        return -sd
    if dev == "sign":
        return -jnp.sign(mu)
    raise ValueError(dev)


def _distance_attack(updates, malicious, *, dev, mode, n_iters=25,
                     gamma_init=10.0):
    """Shared core of min_max / min_sum [Shejwalkar & Houmansadr 2021]:
    the malicious update is mu + gamma * p with the perturbation p a
    deviation direction and gamma the LARGEST value keeping the crafted
    update's distance profile inside the honest clients' own:

      min_max:  max_h ||m - u_h||^2 <= max_{h,h'} ||u_h - u_h'||^2
      min_sum:  sum_h ||m - u_h||^2 <= max_h sum_{h'} ||u_h - u_h'||^2

    Distances are quadratics in gamma, so a fixed bisection (branchless,
    jittable) finds gamma; gamma=0 (crafted == honest mean) is the safe
    fallback when nothing larger is feasible."""
    flat, leaves, treedef = _flatten_clients(updates)
    mu, sd, h, _ = _honest_stats(flat, malicious)
    p = _dev_direction(dev, mu, sd)

    sq = jnp.sum(flat * flat, axis=1)
    d = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (flat @ flat.T), 0.0)
    hh = h[:, None] * h[None, :]
    if mode == "max":
        budget = jnp.max(d * hh)
    else:
        rows = (d * h[None, :]).sum(1)
        budget = jnp.max(jnp.where(h > 0, rows, -jnp.inf))

    diff = mu[None] - flat                          # (K, N)
    a = jnp.sum(diff * diff, axis=1)                # ||mu - u_k||^2
    b = diff @ p
    c = jnp.sum(p * p)

    def feasible(g):
        dist = a + 2.0 * g * b + g * g * c
        if mode == "max":
            return jnp.max(jnp.where(h > 0, dist, -jnp.inf)) <= budget
        return (dist * h).sum() <= budget

    def body(_, carry):
        g, step, best = carry
        ok = feasible(g)
        best = jnp.where(ok, jnp.maximum(best, g), best)
        g = jnp.where(ok, g + step, g - step)
        return g, 0.5 * step, best

    _, _, gamma = jax.lax.fori_loop(
        0, n_iters, body,
        (jnp.float32(gamma_init), jnp.float32(gamma_init) / 2.0,
         jnp.float32(0.0)))
    crafted = mu + gamma * p
    return _unflatten_clients(_replace_malicious(flat, malicious, crafted),
                              leaves, treedef)


def min_max(updates, malicious, *, dev="std", n_iters=25, gamma_init=10.0):
    """Min-max distance attack: see ``_distance_attack``."""
    return _distance_attack(updates, malicious, dev=dev, mode="max",
                            n_iters=n_iters, gamma_init=gamma_init)


def min_sum(updates, malicious, *, dev="std", n_iters=25, gamma_init=10.0):
    """Min-sum distance attack: see ``_distance_attack``."""
    return _distance_attack(updates, malicious, dev=dev, mode="sum",
                            n_iters=n_iters, gamma_init=gamma_init)


class CrossRoundGateAware:
    """Stateful cross-round adaptive attacker (PR-5 follow-up): instead
    of modelling the gate analytically like ``gate_aware``, it PROBES it
    — the carry holds a blend weight b and last round's gate outcome,
    and each round it re-tunes b from whether its colluders were caught:

      caught   (any malicious row gated/rejected last round):
               b <- b + lr * (1 - b)   — retreat toward the reference
      evaded:  b <- b * (1 - lr)       — press the attack harder

    The crafted update is (1-b) * v + b * ref with v the trim-window
    poison corner and ref the anticipated contaminated median (both from
    the ``gate_aware`` machinery), so b=1 is indistinguishable from an
    honest-looking median and b=0 is the full boosted poison.  The carry
    rides the round scan (``FedState.attacker``), so scan==python
    bit-parity holds with the attacker adapting across rounds — and the
    async buffer delivers its STALE probes late, which is exactly the
    evasion channel the ``async_late_poison`` scenario stresses.

    Protocol (``stateful = True``; see core/fedfits.py):
      init(K)                     -> carry (b0, zeros(K))
      __call__(upd, mal, rng, c)  -> (crafted, adapted b)
      observe(b, gated_mask)      -> next carry (b, gated_mask)
    """

    stateful = True

    def __init__(self, cfg, *, scale=100.0, lr=0.5, blend0=0.5):
        self.cfg = cfg
        self.scale = float(scale)
        self.lr = float(lr)
        self.blend0 = float(blend0)

    def init(self, n_clients):
        return (jnp.float32(self.blend0),
                jnp.zeros((n_clients,), jnp.float32))

    def __call__(self, updates, malicious, rng, carry):
        blend, prev_gated = carry
        caught = (prev_gated * malicious).sum() > 0
        blend = jnp.where(caught,
                          blend + self.lr * (1.0 - blend),
                          blend * (1.0 - self.lr))
        flat, leaves, treedef = _flatten_clients(updates)
        v, ref, lo, hi, trims = _gate_aware_targets(
            flat, malicious, self.cfg, scale=self.scale)
        crafted = (1.0 - blend) * v + blend * ref
        if trims:
            crafted = jnp.clip(crafted, lo, hi)
        out = _unflatten_clients(
            _replace_malicious(flat, malicious, crafted), leaves, treedef)
        return out, blend

    def observe(self, blend, gated_mask):
        return (blend, gated_mask)

    @staticmethod
    def gather(carry, idx):
        """Cohort view of a population-scale carry (the async engine
        keeps prev_gated as an (M,) column and hands the attacker just
        the sampled rows)."""
        blend, prev_gated = carry
        return (blend, prev_gated[idx])


def _gate_aware_targets(flat, malicious, cfg, *, scale=100.0):
    """The poison corner v, gate reference ref and trim window (lo, hi)
    shared by ``gate_aware`` (analytic blend) and ``CrossRoundGateAware``
    (probed blend)."""
    _, _, h, nh = _honest_stats(flat, malicious)
    mu = (flat * h[:, None]).sum(0) / nh
    k = flat.shape[0]
    trims = cfg.aggregator != "fedavg"
    asc = jnp.sort(jnp.where(h[:, None] > 0, flat, jnp.inf), axis=0)
    t = jnp.floor(cfg.trim_frac * nh).astype(jnp.int32)
    take = lambda s, i: jnp.take_along_axis(
        s, jnp.broadcast_to(i, (1, flat.shape[1])).astype(jnp.int32), 0)[0]
    lo = take(asc, t)
    desc = jnp.sort(jnp.where(h[:, None] > 0, flat, -jnp.inf), axis=0)
    hi = take(desc, k - 1 - t)
    nh_i = nh.astype(jnp.int32)
    ref = 0.5 * (take(asc, (nh_i - 1) // 2) + take(asc, nh_i // 2))
    if not trims:
        m_cnt = k - nh_i
        side = (mu > 0).astype(jnp.int32)
        lo_r = jnp.clip((k - 1) // 2 - m_cnt * side, 0, nh_i - 1)
        hi_r = jnp.clip(k // 2 - m_cnt * side, 0, nh_i - 1)
        ref = 0.5 * (take(asc, lo_r) + take(asc, hi_r))
        lo, hi = jnp.full_like(lo, -jnp.inf), jnp.full_like(hi, jnp.inf)
    v = jnp.clip(-scale * mu, lo, hi)
    return v, ref, lo, hi, trims


def gate_aware(updates, malicious, cfg, *, margin=0.1, scale=100.0,
               n_iters=20):
    """Defense-aware attacker for the Eq.-11 pipeline: reads
    ``cfg.aggregator``, ``cfg.cosine_outlier_thresh`` and
    ``cfg.trim_frac`` and crafts a colluding update that sits *just
    inside* the defenses actually deployed:

      1. trim window (every robust aggregator): per coordinate, the
         most-adversarial corner of the honest trimmed range [q_lo,
         q_hi] (the t-th / (nh-1-t)-th honest order statistics, t =
         floor(trim_frac * nh)) — pushing against -mu as far as the
         window allows, so a sorting defense cannot excise it as an
         extreme order statistic, and (for Krum) its distances to the
         honest cluster stay comparable to the honest spread.  Against a
         PLAIN-MEAN aggregator no window applies and the raw boosted
         direction ``-scale * mu`` is used (the classic
         model-replacement boost, limited only by the gate).
      2. cosine gate: the crafted vector is blended toward the
         ANTICIPATED gate reference — the coordinate median of the
         cohort *with the crafted points inserted* (the gate's reference
         is computed over all updates, so an attacker aiming at the
         honest median mis-models the gate it is trying to evade and
         gets caught by its own contamination) — by the SMALLEST weight
         whose tree-wide cosine clears ``thresh + margin`` (bisected,
         branchless), then re-clamped to the trim window when one
         applies.
    """
    flat, leaves, treedef = _flatten_clients(updates)
    mu, _, h, nh = _honest_stats(flat, malicious)
    k = flat.shape[0]
    trims = cfg.aggregator != "fedavg"

    # honest order statistics: ascending sort with malicious rows at +inf
    # puts the nh honest values first; t-th row is the lower trim bound
    asc = jnp.sort(jnp.where(h[:, None] > 0, flat, jnp.inf), axis=0)
    t = jnp.floor(cfg.trim_frac * nh).astype(jnp.int32)
    take = lambda s, i: jnp.take_along_axis(
        s, jnp.broadcast_to(i, (1, flat.shape[1])).astype(jnp.int32), 0)[0]
    lo = take(asc, t)
    # descending bound: malicious at -inf pushes honest rows to the END
    desc = jnp.sort(jnp.where(h[:, None] > 0, flat, -jnp.inf), axis=0)
    hi = take(desc, k - 1 - t)
    nh_i = nh.astype(jnp.int32)
    ref = 0.5 * (take(asc, (nh_i - 1) // 2) + take(asc, nh_i // 2))
    if not trims:
        # anticipated contaminated median: the m crafted values land
        # BELOW every honest value where mu > 0 (the boosted direction
        # is -scale*mu) and ABOVE where mu < 0, shifting the all-updates
        # median onto a known honest order statistic per coordinate
        m_cnt = k - nh_i
        side = (mu > 0).astype(jnp.int32)           # crafted on low side
        lo_r = jnp.clip((k - 1) // 2 - m_cnt * side, 0, nh_i - 1)
        hi_r = jnp.clip(k // 2 - m_cnt * side, 0, nh_i - 1)
        ref = 0.5 * (take(asc, lo_r) + take(asc, hi_r))
        lo, hi = jnp.full_like(lo, -jnp.inf), jnp.full_like(hi, jnp.inf)

    v = jnp.clip(-scale * mu, lo, hi)               # trim-window corner
    target = jnp.float32(cfg.cosine_outlier_thresh + margin)
    rn = jnp.sqrt(jnp.sum(ref * ref))

    def cos_w(w):
        u = (1.0 - w) * v + w * ref
        un = jnp.sqrt(jnp.sum(u * u))
        return jnp.sum(u * ref) / jnp.maximum(un * rn, _EPS)

    def body(_, bounds):
        lo_w, hi_w = bounds
        mid = 0.5 * (lo_w + hi_w)
        ok = cos_w(mid) >= target
        return jnp.where(ok, lo_w, mid), jnp.where(ok, mid, hi_w)

    # w=1 is always feasible (cos=1); find the smallest feasible blend
    _, w = jax.lax.fori_loop(
        0, n_iters, body, (jnp.float32(0.0), jnp.float32(1.0)))
    w = jnp.where(cos_w(jnp.float32(0.0)) >= target, jnp.float32(0.0), w)
    crafted = (1.0 - w) * v + w * ref
    if trims:
        crafted = jnp.clip(crafted, lo, hi)
    else:
        # the blend can near-cancel ||v|| against ||ref||; the gate only
        # sees direction, so restore the boosted magnitude along it
        cn = jnp.sqrt(jnp.sum(crafted * crafted))
        crafted = crafted * (scale * jnp.sqrt(jnp.sum(mu * mu))
                             / jnp.maximum(cn, _EPS))
    return _unflatten_clients(_replace_malicious(flat, malicious, crafted),
                              leaves, treedef)
