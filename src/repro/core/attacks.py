"""Poisoning attack models (paper §VI: data & model poisoning) for the
robustness experiments. Data attacks corrupt the client's batch; model
attacks corrupt the client's *update* before it reaches the server.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- data ----
def label_flip(labels, n_classes, malicious, *, mode="shift"):
    """Flip labels of malicious clients. labels: (K, B); malicious: (K,) 0/1.

    mode 'shift': y -> (y+1) % C (paper's label-flipping attack);
    mode 'target': everything -> class 0 (targeted).
    """
    if mode == "shift":
        flipped = jnp.mod(labels + 1, n_classes)
    else:
        flipped = jnp.zeros_like(labels)
    m = malicious.reshape((-1,) + (1,) * (labels.ndim - 1))
    return jnp.where(m > 0, flipped, labels)


def backdoor_trigger(images, labels, malicious, *, target=0, patch=3):
    """Stamp a white patch in the corner + relabel to target (backdoor)."""
    trig = images.at[..., :patch, :patch, :].set(1.0)
    m_im = malicious.reshape((-1,) + (1,) * (images.ndim - 1))
    m_lb = malicious.reshape((-1,) + (1,) * (labels.ndim - 1))
    return (jnp.where(m_im > 0, trig, images),
            jnp.where(m_lb > 0, jnp.full_like(labels, target), labels))


def feature_noise(x, malicious, sigma, rng):
    """Gaussian feature corruption (tabular/image)."""
    noise = sigma * jax.random.normal(rng, x.shape, x.dtype)
    m = malicious.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(m > 0, x + noise, x)


# --------------------------------------------------------------- model ----
def sign_flip(updates, malicious, *, scale=1.0):
    """Byzantine sign-flip: u -> -scale * u for malicious clients."""
    def leaf(l):
        m = malicious.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
        return l * (1.0 - m) + (-scale) * l * m

    return jax.tree_util.tree_map(leaf, updates)


def gaussian_update(updates, malicious, sigma, rng):
    """Replace malicious updates with pure noise."""
    flat, treedef = jax.tree_util.tree_flatten(updates)
    keys = jax.random.split(rng, len(flat))

    out = []
    for l, k in zip(flat, keys):
        m = malicious.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
        noise = sigma * jax.random.normal(k, l.shape, l.dtype)
        out.append(l * (1.0 - m) + noise * m)
    return jax.tree_util.tree_unflatten(treedef, out)


def scale_attack(updates, malicious, gamma):
    """Model-replacement scaling: u -> gamma * u (boosted poisoning)."""
    def leaf(l):
        m = malicious.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
        return l * (1.0 + (gamma - 1.0) * m)

    return jax.tree_util.tree_map(leaf, updates)
