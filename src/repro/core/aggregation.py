"""Robust, trust-aware aggregation A(.) of per-client updates (paper Eq. 11).

Updates are pytrees whose leaves carry a leading client axis (K, ...).
Every aggregator takes a float mask (K,) — only masked-in clients count —
and weights (K,) already normalised by the caller.

  fedavg        weighted mean (memory-light; the big-arch default)
  median        coordinate-wise masked median
  trimmed_mean  coordinate-wise masked trimmed mean
  krum          (multi-)Krum by pairwise distances

plus the trust machinery: EWMA trust decay and gradient-cosine outlier
gating, and the two-stage slot-internal -> cross-slot combine.

The full Eq.-11 pipeline (median reference -> cosine gate -> aggregator)
runs by default through the fused two-pass Pallas engine in
kernels/robust_pipeline.py (``aggregate``/``two_stage`` dispatch on
cfg.fused_agg); the multi-pass XLA implementations here remain the
parity oracles (``aggregate_ref``/``two_stage_ref``).  The engine
streams pytrees leaf-wise (segment-table grid, no flatten concatenate)
with the block size autotuned unless cfg.agg_blk pins it.  On a mesh,
``aggregate_sharded`` runs the same pipeline with the flattened param
axis sharded over devices (shard_map): both passes stream shard-locally
and only the (C,) cosine partials / Krum Gram matrix cross devices in
one psum.  The standalone kernel in kernels/robust_agg.py keeps the
bare masked trimmed-mean / median contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BIG = 1e30


def normalize_weights(weights, mask):
    w = weights * mask
    return w / jnp.maximum(w.sum(), 1e-12)


def sanitize_updates(updates, mask, *, norm_mult=1e4):
    """Aggregation-boundary hardening: reject non-finite (NaN/Inf) or
    absurd-norm client deliveries BEFORE any aggregator sees them.

    A crashed client ships NaNs, a hostile one ships 1e30-scale rows —
    either one entering even a single coordinate of the global model is
    unrecoverable (NaN propagates through every later round), and the
    robust aggregators do NOT cover it: a nan row poisons the sort-based
    order statistics and the cosine gate's own reference.  Runs on the
    raw delivered rows ahead of BOTH the fused Pallas and XLA reference
    paths, so both are covered by construction.

    Rejection rule per masked-in client row:
      * any non-finite coordinate anywhere in its update tree, or
      * tree-wide L2 norm > ``norm_mult`` x the median norm of the
        finite masked-in rows (``norm_mult`` <= 0 disables the norm
        rule; the finiteness rule always applies).  The threshold is
        RELATIVE — absolute scales are model/lr-dependent — and the
        default 1e4 headroom keeps every legitimate attack scenario
        (10x sign-flip, ALIE) untouched: this guard is for absurd rows,
        the Eq.-11 pipeline handles the adversarial-but-plausible ones.

    Returns ``(clean_updates, clean_mask, rejected)``: rejected rows are
    zeroed and masked out (an all-rejected cohort therefore hits the
    aggregators' empty-mask path and yields a ZERO update), and
    ``rejected`` (K,) 0/1 lets the caller charge a trust penalty.  With
    all-finite sane inputs the outputs are bit-identical passthroughs.
    """
    k = mask.shape[0]
    finite = jnp.ones((k,), bool)
    sq = jnp.zeros((k,), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(updates):
        f = leaf.reshape(k, -1).astype(jnp.float32)
        ok = jnp.isfinite(f)
        finite = finite & ok.all(axis=1)
        sq = sq + jnp.sum(jnp.where(ok, f, 0.0) ** 2, axis=1)
    norm = jnp.sqrt(sq)
    good = finite & (mask > 0)
    if norm_mult and norm_mult > 0:
        # masked median of the finite rows' norms (the reference scale)
        n_good = good.sum()
        s = jnp.sort(jnp.where(good, norm, jnp.inf))
        lo = jnp.floor(jnp.maximum(n_good - 1, 0) / 2).astype(jnp.int32)
        hi = jnp.ceil(jnp.maximum(n_good - 1, 0) / 2).astype(jnp.int32)
        med = 0.5 * (s[lo] + s[hi])
        med = jnp.where(n_good > 0, med, 0.0)
        sane = norm <= norm_mult * jnp.maximum(med, 1e-12)
        ok_row = finite & sane
    else:
        ok_row = finite
    rejected = ((mask > 0) & ~ok_row).astype(jnp.float32)
    okf = ok_row.astype(jnp.float32)
    clean = jax.tree_util.tree_map(
        lambda l: jnp.where(
            okf.reshape((k,) + (1,) * (l.ndim - 1)) > 0, l,
            jnp.zeros_like(l)),
        updates)
    return clean, mask * okf, rejected


def rejection_kinds(updates, mask, *, norm_mult=1e4):
    """Telemetry readout of the guard's decision, split by KIND: returns
    ``(nonfinite, norm)`` 0/1 (K,) vectors with ``nonfinite + norm ==
    rejected`` of :func:`sanitize_updates` on the same inputs (a row
    failing both counts as nonfinite — the finiteness rule fires first).
    Shares its reductions with the guard itself, so inside one jit XLA
    CSE makes the extra accounting free."""
    k = mask.shape[0]
    finite = jnp.ones((k,), bool)
    sq = jnp.zeros((k,), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(updates):
        f = leaf.reshape(k, -1).astype(jnp.float32)
        ok = jnp.isfinite(f)
        finite = finite & ok.all(axis=1)
        sq = sq + jnp.sum(jnp.where(ok, f, 0.0) ** 2, axis=1)
    norm = jnp.sqrt(sq)
    good = finite & (mask > 0)
    in_mask = mask > 0
    nonfinite = (in_mask & ~finite).astype(jnp.float32)
    if norm_mult and norm_mult > 0:
        n_good = good.sum()
        s = jnp.sort(jnp.where(good, norm, jnp.inf))
        lo = jnp.floor(jnp.maximum(n_good - 1, 0) / 2).astype(jnp.int32)
        hi = jnp.ceil(jnp.maximum(n_good - 1, 0) / 2).astype(jnp.int32)
        med = 0.5 * (s[lo] + s[hi])
        med = jnp.where(n_good > 0, med, 0.0)
        sane = norm <= norm_mult * jnp.maximum(med, 1e-12)
        norm_rej = (in_mask & finite & ~sane).astype(jnp.float32)
    else:
        norm_rej = jnp.zeros((k,), jnp.float32)
    return nonfinite, norm_rej


def weighted_mean(updates, weights, mask):
    w = normalize_weights(weights, mask)

    def agg(leaf):
        return jnp.tensordot(w.astype(leaf.dtype), leaf, axes=(0, 0))

    return jax.tree_util.tree_map(agg, updates)


def _masked_sorted(leaf, mask):
    """Sort clients per coordinate with masked-out clients pushed to +inf."""
    k = leaf.shape[0]
    m = mask.reshape((k,) + (1,) * (leaf.ndim - 1))
    return jnp.sort(jnp.where(m > 0, leaf.astype(jnp.float32), _BIG), axis=0)

def median(updates, mask):
    """Coordinate-wise median over masked-in clients.  An empty cohort
    (all-zero mask) returns a ZERO update: the three-phase protocol makes
    empty participation masks a normal state, and the unclamped rank
    index (-1 wraps to the last sorted entry) used to leak the ``_BIG``
    masked-out sentinel into the global model."""
    n = mask.sum()

    def agg(leaf):
        s = _masked_sorted(leaf, mask)
        k = leaf.shape[0]
        # indices of the middle element(s) among the first n sorted
        # entries, clamped to n >= 1 so an empty mask cannot index -1
        lo = jnp.floor(jnp.maximum(n - 1, 0) / 2).astype(jnp.int32)
        hi = jnp.ceil(jnp.maximum(n - 1, 0) / 2).astype(jnp.int32)
        take = lambda i: jnp.take_along_axis(
            s, jnp.broadcast_to(i, (1,) + leaf.shape[1:]).astype(jnp.int32), 0)[0]
        out = 0.5 * (take(lo) + take(hi))
        return jnp.where(n > 0, out, 0.0).astype(leaf.dtype)

    return jax.tree_util.tree_map(agg, updates)


def trimmed_mean(updates, mask, trim_frac):
    """Coordinate-wise mean after dropping trim_frac per side (of n selected)."""
    n = mask.sum()
    t = jnp.floor(trim_frac * n).astype(jnp.int32)

    def agg(leaf):
        s = _masked_sorted(leaf, mask)
        k = leaf.shape[0]
        idx = jnp.arange(k).reshape((k,) + (1,) * (leaf.ndim - 1))
        keep = (idx >= t) & (idx < (n - t).astype(jnp.int32))
        cnt = jnp.maximum(n - 2 * t, 1.0)
        return (jnp.where(keep, s, 0.0).sum(0) / cnt).astype(leaf.dtype)

    return jax.tree_util.tree_map(agg, updates)


def pairwise_sq_dists(updates, mask):
    """(K, K) squared distances between flattened client updates."""
    def leaf_d(leaf):
        f = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        sq = jnp.sum(f * f, axis=1)
        return sq[:, None] + sq[None, :] - 2.0 * (f @ f.T)

    d = sum(jax.tree_util.tree_leaves(jax.tree_util.tree_map(leaf_d, updates)))
    big = _BIG * (1 - mask[:, None] * mask[None, :])
    return jnp.maximum(d, 0.0) + big


def krum(updates, mask, f, *, multi_m=1):
    """(Multi-)Krum [Blanchard et al. 2017]. Scores each client by the sum of
    its n - f - 2 smallest distances to other selected clients; averages the
    multi_m best."""
    d = pairwise_sq_dists(updates, mask)
    k = d.shape[0]
    d = d + _BIG * jnp.eye(k)                     # exclude self
    n = mask.sum()
    closest = jnp.sort(d, axis=1)
    j = jnp.arange(k, dtype=jnp.float32)[None, :]
    take = jnp.maximum(n - f - 2, 1.0)
    scores = jnp.where(j < take, closest, 0.0).sum(1)
    # masked-out clients rank past every real one — inf, not _BIG: a lone
    # selected client's score is _BIG + d (its distances are to masked
    # peers) and must still beat the excluded rows
    scores = jnp.where(mask > 0, scores, jnp.inf)
    order = jnp.argsort(scores)
    # restrict winners to masked-in clients: an empty cohort must yield a
    # zero update, not an arbitrary client's (all scores tie at _BIG)
    sel = jnp.zeros((k,), jnp.float32).at[order[:multi_m]].set(1.0) * mask
    return weighted_mean(updates, sel, sel)


def cosine_to_ref(updates, ref):
    """Tree-wide cosine similarity (K,) of each client's update vs. a
    reference direction pytree (one streaming pass, no sort)."""
    def dot_leaf(leaf, rleaf):
        f = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        r = rleaf.reshape(-1).astype(jnp.float32)
        return f @ r, jnp.sum(f * f, axis=1), jnp.sum(r * r)

    dots, n1, n2 = 0.0, 0.0, 0.0
    for leaf, rleaf in zip(jax.tree_util.tree_leaves(updates),
                           jax.tree_util.tree_leaves(ref)):
        d, a, b = dot_leaf(leaf, rleaf)
        dots, n1, n2 = dots + d, n1 + a, n2 + b
    return dots / jnp.maximum(jnp.sqrt(n1 * n2), 1e-12)


def cosine_outlier_mask(updates, ref, mask, thresh):
    """Gate clients whose update has cosine similarity < thresh vs. a
    reference direction (e.g. the trust-weighted mean). Returns 0/1 (K,)."""
    cos = cosine_to_ref(updates, ref)
    return ((cos >= thresh) & (mask > 0)).astype(jnp.float32)


def update_trust(trust, scores, mask, decay):
    """EWMA trust: selected clients' trust tracks their normalised score;
    unselected clients keep (decayed-toward-neutral) trust."""
    smax = jnp.maximum(jnp.max(scores * mask), 1e-12)
    norm_score = jnp.clip(scores / smax, 0.0, 1.0)
    upd = decay * trust + (1.0 - decay) * norm_score
    hold = decay * trust + (1.0 - decay) * 0.5     # drift to neutral
    return jnp.where(mask > 0, upd, hold)


def aggregate_ref(updates, weights, mask, cfg):
    """Multi-pass XLA reference for the Eq.-11 pipeline: applies the
    gradient-cosine outlier gate first (robust pipeline of DESIGN.md §1
    item 5), then the configured aggregator.  Kept as the parity oracle
    for the fused Pallas engine (kernels/robust_pipeline.py), which
    replaces these ~4 sort-based passes with 2 streaming passes.

    The gate's reference direction is the coordinate MEDIAN, not the mean:
    a mean reference is itself corruptible (large-magnitude poison flips
    the reference's sign and the gate would then excise the honest
    clients)."""
    ref = median(updates, mask)
    gate = cosine_outlier_mask(updates, ref, mask, cfg.cosine_outlier_thresh)
    m = mask * gate
    # never gate everyone out; an INCOMING all-zero mask (empty cohort —
    # a normal state of the slotted protocol) falls through to the
    # aggregators, each of which returns a zero update for it
    m = jnp.where(m.sum() > 0, m, mask)
    if cfg.aggregator == "fedavg":
        return weighted_mean(updates, weights, m)
    if cfg.aggregator == "median":
        return median(updates, m)
    if cfg.aggregator == "trimmed_mean":
        return trimmed_mean(updates, m, cfg.trim_frac)
    if cfg.aggregator == "krum":
        return krum(updates, m, cfg.krum_f)
    raise ValueError(cfg.aggregator)


def aggregate(updates, weights, mask, cfg):
    """Dispatch on cfg.aggregator.  Routes through the fused two-pass
    Pallas engine (kernels/robust_pipeline.py; interpret mode off-TPU)
    unless cfg.fused_agg is False, in which case the multi-pass XLA
    reference runs instead."""
    if getattr(cfg, "fused_agg", True):
        from repro.kernels.robust_pipeline import fused_aggregate_tree
        return fused_aggregate_tree(updates, weights, mask, cfg,
                                    blk=getattr(cfg, "agg_blk", None))
    return aggregate_ref(updates, weights, mask, cfg)


def aggregate_sharded(updates, weights, mask, cfg, mesh, axes=None):
    """Mesh-sharded Eq.-11 aggregation over a pytree of (C, ...) leaves.

    Each leaf's flattened parameter axis is sharded over the ``axes``
    mesh axes (default: every axis except "pod"), so every device streams
    only its shard through both fused passes; only the (C,) cosine
    partials — and Krum's (C, C) Gram matrix — cross devices, in one
    psum.  Leaves whose size does not divide the axis extent stay
    replicated; a 0/1 per-leaf scale keeps them from being double-counted
    in the psum.  Semantically equivalent to ``aggregate`` (parity atol
    ~1e-5 from the shard-local summation order)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels import robust_pipeline as rp
    from repro.sharding import specs as sh

    if axes is None:
        axes = tuple(a for a in mesh.axis_names if a != "pod")
    axes = tuple(axes)
    leaves, treedef = jax.tree_util.tree_flatten(updates)
    C = leaves[0].shape[0]
    flat = [l.reshape(1, C, -1) for l in leaves]          # views, no copy
    in_shardings, shard_flags = sh.client_flat_shardings(
        [f.shape[-1] for f in flat], mesh, axes)
    in_specs = tuple(s.spec for s in in_shardings)
    out_specs = tuple(P(None, axes) if f else P(None, None)
                      for f in shard_flags)
    # constrain the flat views to the shard_map input layout BEFORE the
    # boundary: GSPMD then materialises the producer's outputs (e.g. the
    # vmap'd per-client backward) directly in the (C, shard) layout, so
    # the shard_map entry is a no-op instead of an all-to-all reshard
    # (jaxpr-guarded in tests/test_sharded_agg.py)
    flat = [jax.lax.with_sharding_constraint(f, s)
            for f, s in zip(flat, in_shardings)]

    def agg(w, m, *fl):
        own = jnp.float32(1.0)
        for a in axes:                                    # linear-index == 0
            own = own * (jax.lax.axis_index(a) == 0).astype(jnp.float32)
        scale = jnp.stack([jnp.float32(1.0) if f else own
                           for f in shard_flags])
        outs = rp.fused_pipeline_leafwise(
            list(fl), w[None], m[None],
            aggregator=cfg.aggregator, trim_frac=cfg.trim_frac,
            cosine_thresh=cfg.cosine_outlier_thresh, krum_f=cfg.krum_f,
            blk=getattr(cfg, "agg_blk", None),
            axis_name=axes, leaf_scale=scale,
            out_dtypes=[l.dtype for l in leaves])
        return tuple(outs)

    wrapped = shard_map(agg, mesh=mesh,
                        in_specs=(P(None), P(None)) + tuple(in_specs),
                        out_specs=out_specs, check_rep=False)
    outs = wrapped(weights, mask, *flat)
    outs = [o.reshape(l.shape[1:]) for o, l in zip(outs, leaves)]
    return jax.tree_util.tree_unflatten(treedef, outs)


def two_stage_ref(slot_updates, slot_weights, slot_masks, cfg):
    """Cohort-batched reference for the two-stage scheme: ``aggregate_ref``
    vmapped over the leading cohort axis (matching the fused kernel's
    cohort-grid semantics — no serially-traced Python loop), then the
    cross-slot size-weighted mean."""
    per_cohort = jax.vmap(
        lambda u, w, m: aggregate_ref(u, w, m, cfg)
    )(slot_updates, slot_weights, slot_masks)
    cw = slot_masks.sum(axis=1).astype(jnp.float32)
    cw = cw / jnp.maximum(cw.sum(), 1e-12)
    return jax.tree_util.tree_map(
        lambda l: jnp.tensordot(cw.astype(l.dtype), l, axes=(0, 0)),
        per_cohort)


def two_stage(slot_updates, slot_weights, slot_masks, cfg):
    """Slot-internal robust aggregation per cohort, then cross-slot mean —
    the paper's two-stage scheme; on the pod this is psum(data) then
    psum(pod). Here: cohort-major pytrees (n_cohorts leading axis).  All
    cohorts ride the G grid axis of ONE fused ``pallas_call`` when
    cfg.fused_agg (default); the vmapped XLA oracle runs otherwise."""
    if getattr(cfg, "fused_agg", True):
        from repro.kernels.robust_pipeline import fused_two_stage_tree
        return fused_two_stage_tree(slot_updates, slot_weights, slot_masks,
                                    cfg, blk=getattr(cfg, "agg_blk", None))
    return two_stage_ref(slot_updates, slot_weights, slot_masks, cfg)
