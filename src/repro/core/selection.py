"""Client-selection policies: FedFiTS threshold election (+ fairness floors,
explore-exploit), and the paper's baselines FedAvg / FedRand / FedPow.

All policies return a float32 mask (K,) — X(k, t) of Eq. (8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fitness


def fedfits_select(scores, beta, avail, rng, *, floor_prob=0.0,
                   explore_eps=0.0, min_team=1):
    """Threshold-aware election (Eqs. 3, 7-8) with fairness extensions.

    floor_prob: A4 participation floor — every available client is force-
      included with prob >= floor_prob regardless of score (prevents
      starvation; bounds eps_sel in the convergence analysis).
    explore_eps: explore-exploit — below-threshold clients are admitted
      with prob explore_eps (utility drift re-discovery).
    min_team: keep at least this many clients (top-score fallback).
    """
    thr = fitness.threshold(scores, beta, avail)
    base = (scores >= thr).astype(jnp.float32) * avail

    r1, r2 = jax.random.split(rng)
    floor = (jax.random.uniform(r1, scores.shape) < floor_prob).astype(jnp.float32)
    explore = (jax.random.uniform(r2, scores.shape) < explore_eps).astype(jnp.float32)
    mask = jnp.clip(base + (floor + explore) * avail, 0.0, 1.0)

    # fallback: if the team came out empty, take the best available client(s)
    k = scores.shape[0]
    order = jnp.argsort(jnp.where(avail > 0, -scores, jnp.inf))
    top = jnp.zeros((k,)).at[order[:min_team]].set(1.0) * avail
    return jnp.where(mask.sum() >= min_team, mask, jnp.clip(mask + top, 0, 1))


def fedavg_select(avail):
    """FedAvg (c=1.0): everyone available."""
    return avail


def fedrand_select(avail, c, rng):
    """FedRand: uniform random m = ceil(c*K_avail) clients."""
    k = avail.shape[0]
    m = jnp.maximum(jnp.ceil(c * avail.sum()), 1.0)
    u = jax.random.uniform(rng, (k,))
    pri = jnp.where(avail > 0, u, -jnp.inf)
    order = jnp.argsort(-pri)
    ranks = jnp.zeros((k,), jnp.float32).at[order].set(
        jnp.arange(k, dtype=jnp.float32))
    return ((ranks < m) & (avail > 0)).astype(jnp.float32)


def fedpow_select(local_losses, avail, d, m, rng, n=None):
    """Power-of-choice [Cho et al. 2020]: sample a candidate set of size d
    WITHOUT replacement proportional to the data fraction n_k (the
    paper's candidate distribution), then pick the m with highest local
    loss.

    The ∝ n_k draw uses Gumbel-top-d: top-d of log(n_k) + Gumbel noise is
    a without-replacement sample from the n_k-proportional distribution
    (Efraimidis-Spirakis).  n=None falls back to uniform candidates
    (all-equal weights)."""
    k = avail.shape[0]
    if n is None:
        logw = jnp.zeros((k,), jnp.float32)
    else:
        logw = jnp.log(jnp.maximum(n.astype(jnp.float32), 1e-12))
    u = logw + jax.random.gumbel(rng, (k,))
    cand_pri = jnp.where(avail > 0, u, -jnp.inf)
    cand_order = jnp.argsort(-cand_pri)
    cand_rank = jnp.zeros((k,), jnp.float32).at[cand_order].set(
        jnp.arange(k, dtype=jnp.float32))
    cand = (cand_rank < d) & (avail > 0)

    loss_pri = jnp.where(cand, local_losses, -jnp.inf)
    sel_order = jnp.argsort(-loss_pri)
    sel_rank = jnp.zeros((k,), jnp.float32).at[sel_order].set(
        jnp.arange(k, dtype=jnp.float32))
    return ((sel_rank < m) & cand).astype(jnp.float32)


def population_cohort(priority, d, rng, *, method="segmented", blk=4096):
    """Population-scale cohort sampling: d of M clients WITHOUT
    replacement, with probability proportional to ``priority`` (M,).

    Same Efraimidis-Spirakis Gumbel-top-d trick as fedpow's candidate
    draw above, but routed through the streaming O(M) top-d kernels
    (``kernels/population_select.py``: segmented-XLA or blocked-Pallas
    reduction) instead of a dense argsort — the path the buffered-async
    engine samples a 64-client cohort from a million-row ClientStore
    with.  Returns (d,) int32 population indices, descending key order
    (identical across kernel engines, so swapping ``method`` preserves
    scan==python bit-parity)."""
    logw = jnp.log(jnp.maximum(priority.astype(jnp.float32), 1e-12))
    from repro.kernels import population_select as ps
    return ps.gumbel_topd(logw, d, rng, method=method, blk=blk)


def participation_ratio(cum_selected):
    """Fraction of clients selected at least once (paper Table VI proxy)."""
    return (cum_selected > 0).mean()
