"""Slotted team scheduling (paper §III, Eqs. (4)-(5)) — branchless/jittable.

  p(t+1) = p(t)+1 if theta(t) < theta(t-1) else 0          (Eq. 4)
  h(t+1) = p(t+1) >= PFT  or  (t+1) % MSL == 0  or  t == 1 (Eq. 5 + Alg. 1)

plus the *adaptive slot* extension (paper Table II "adaptive team slots"):
MSL is scaled by the observed team-performance variance — stable teams get
longer slots, volatile ones get reassessed sooner.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SlotState(NamedTuple):
    p: jnp.ndarray            # consecutive-decline counter, i32
    prev_theta: jnp.ndarray   # theta(t-1), f32
    theta_ema: jnp.ndarray    # EMA of team theta (adaptive slots), f32
    theta_var: jnp.ndarray    # EMA of squared deviation, f32


def init_slot_state():
    return SlotState(p=jnp.int32(0), prev_theta=jnp.float32(-jnp.inf),
                     theta_ema=jnp.float32(0.0), theta_var=jnp.float32(0.0))


def update(state: SlotState, theta_t, t, msl, pft, *, adaptive=False,
           ema_decay=0.9):
    """Returns (new_state, h_next: bool array) for round t (1-indexed).

    Matches Algorithm 1: the decline counter only starts once two team
    evaluations exist (t > 2), and h is forced True at t=1 so round 2 is
    still free-for-all.
    """
    declined = theta_t < state.prev_theta
    p_next = jnp.where((t > 2) & declined, state.p + 1, jnp.int32(0))

    first = jnp.isinf(state.prev_theta)     # EMA warmup: seed on first obs
    ema_prev = jnp.where(first, theta_t, state.theta_ema)
    ema = ema_decay * ema_prev + (1 - ema_decay) * theta_t
    var = jnp.where(
        first, jnp.float32(0.0),
        ema_decay * state.theta_var + (1 - ema_decay)
        * jnp.square(theta_t - ema))

    if adaptive:
        # variance-scaled slot length: rel. std 0 -> 2*MSL, large -> MSL/2
        rel = jnp.sqrt(var) / jnp.maximum(jnp.abs(ema), 1e-6)
        msl_eff = jnp.clip(jnp.round(msl * (2.0 - 3.0 * jnp.minimum(rel, 0.5))),
                           jnp.maximum(msl // 2, 1), 2 * msl).astype(jnp.int32)
    else:
        msl_eff = jnp.int32(msl)

    h_next = (p_next >= pft) | (jnp.mod(t + 1, msl_eff) == 0) | (t == 1)
    return SlotState(p=p_next, prev_theta=theta_t, theta_ema=ema,
                     theta_var=var), h_next
