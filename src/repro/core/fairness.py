"""Fairness reporting for the round metrics (the central theme of both
Annapareddy healthcare-FL papers, PAPERS.md): how evenly does the global
model serve the client population, and how evenly does the scheduler
spread participation?

All metrics are pure jnp (they ride the scan carry's metric history) and
mask-aware — unavailable clients never contribute:

  accuracy_variance    Var_k[acc_k] over available clients (the global
                       model's per-client accuracy spread).
  worst_decile         mean accuracy of the worst ceil(0.1 * n_avail)
                       clients — the tail the variance hides.
  participation_gini   Gini coefficient of cumulative selection counts
                       (0 = perfectly even participation, -> 1 = a few
                       clients monopolise the slots).
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12


def accuracy_variance(acc, mask=None):
    """Variance of per-client accuracy over masked-in clients."""
    if mask is None:
        mask = jnp.ones_like(acc)
    n = jnp.maximum(mask.sum(), 1.0)
    mu = (acc * mask).sum() / n
    return (mask * jnp.square(acc - mu)).sum() / n


def worst_decile(acc, mask=None):
    """Mean accuracy of the bottom ceil(10%) of masked-in clients."""
    if mask is None:
        mask = jnp.ones_like(acc)
    n = mask.sum()
    d = jnp.maximum(jnp.ceil(0.1 * n), 1.0)
    vals = jnp.sort(jnp.where(mask > 0, acc, jnp.inf))
    take = (jnp.arange(acc.shape[0], dtype=jnp.float32) < d).astype(
        jnp.float32)
    worst = (jnp.where(jnp.isfinite(vals), vals, 0.0) * take).sum() / d
    return jnp.where(n > 0, worst, 0.0)


def participation_gini(cum_selected):
    """Gini coefficient of the per-client cumulative selection counts."""
    x = jnp.sort(cum_selected.astype(jnp.float32))
    n = jnp.float32(x.shape[0])
    tot = x.sum()
    i = jnp.arange(1, x.shape[0] + 1, dtype=jnp.float32)
    g = 2.0 * (i * x).sum() / (n * jnp.maximum(tot, _EPS)) - (n + 1.0) / n
    return jnp.where(tot > 0, g, 0.0)


def round_fairness(acc, avail, cum_selected):
    """The per-round fairness block of the metrics dict."""
    return {
        "fair_acc_var": accuracy_variance(acc, avail),
        "fair_worst_decile": worst_decile(acc, avail),
        "fair_part_gini": participation_gini(cum_selected),
    }
