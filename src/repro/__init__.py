"""repro: FedFiTS — fitness-selected, slotted client scheduling for
trustworthy federated learning, as a production-grade multi-pod JAX
framework. See README.md / DESIGN.md."""

__version__ = "0.1.0"
