"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively with the KV/SSM caches (the decode_32k / long_500k path
at laptop scale).

  PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import build


def make_decode_step(model, *, temperature=1.0):
    """One jittable autoregressive decode step: run the model on the last
    token, then sample (temperature > 0) or argmax the next one.

    Returns ``step(params, tok, cache, pos, key) -> (tok', cache', key')``
    with the PRNG key advanced through ``jax.random.split`` every step —
    the serving loop threads the returned key, never reusing one (this is
    a registered entry point of ``repro.analysis``; the lint CLI audits
    exactly that discipline)."""

    def step(params, tok, cache, pos, key):
        logits, cache = model.decode(params, {"tokens": tok}, cache, pos)
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        return nxt, cache, key

    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ring", action="store_true",
                    help="sliding-window ring cache (long-context mode)")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ring and not cfg.sliding_window:
        cfg = cfg.replace(sliding_window=max(32, args.prompt_len // 2))
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    cache = model.init_cache(B, max_len, ring=args.ring, dtype=jnp.float32)

    prefill = jax.jit(model.prefill)
    decode_step = jax.jit(make_decode_step(model,
                                           temperature=args.temperature))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cache)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        tok, cache, key = decode_step(params, tok, cache,
                                      jnp.int32(P + i), key)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    decode_s = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "batch": B, "prompt_len": P, "generated": G,
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "tok_per_s": round(B * (G - 1) / max(decode_s, 1e-9), 1),
        "sample_tokens": gen[0, :16].tolist(),
    }))


if __name__ == "__main__":
    main()
