"""Serving driver: continuous batching over the paged KV cache
(repro/serve/), with the fixed-batch dense-cache loop kept as baselines.

  PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm --reduced \
      --requests 16 --max-slots 4 --page-size 8 --prompt-len 8 \
      --gen-min 16 --gen-max 64 [--engine continuous|fixed|dense] \
      [--kv-int8] [--telemetry-jsonl obs.jsonl] [--trace trace.json]

Engines:
  continuous  slot scheduler + paged KV + flash-decode (the default)
  fixed       same compiled programs, batch-until-drained admission —
              the scheduling baseline for the BENCH serve/* rows
  dense       the original fixed-batch full-cache loop (make_decode_step)

Per-request generation lengths are drawn log-uniformly in
[--gen-min, --gen-max] (mixed-length workload: the regime where
continuous batching wins).
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.model import build


def make_decode_step(model, *, temperature=1.0):
    """One jittable autoregressive decode step: run the model on the last
    token, then sample (temperature > 0) or argmax the next one.

    Returns ``step(params, tok, cache, pos, key) -> (tok', cache', key')``
    with the PRNG key advanced through ``jax.random.split`` every step —
    the serving loop threads the returned key, never reusing one (this is
    a registered entry point of ``repro.analysis``; the lint CLI audits
    exactly that discipline)."""

    def step(params, tok, cache, pos, key):
        logits, cache = model.decode(params, {"tokens": tok}, cache, pos)
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits[:, -1] / temperature)[:, None]
        else:
            nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        return nxt, cache, key

    return step


def draw_requests(n, prompt_len, gen_min, gen_max, vocab, seed=0):
    """Mixed-length synthetic workload: log-uniform generation budgets."""
    from repro.serve import Request
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        gen = int(round(math.exp(rng.uniform(math.log(gen_min),
                                             math.log(gen_max)))))
        prompt = tuple(rng.randint(0, vocab, prompt_len).tolist())
        reqs.append(Request(i, prompt, max(gen, 1)))
    return reqs


def run_dense(model, cfg, args, key):
    """The original fixed-batch full-cache loop (every request padded to
    the longest generation)."""
    params = model.init(key)
    B, P, G = args.max_slots, args.prompt_len, args.gen_max
    prompts = jax.random.randint(key, (args.requests, P), 0,
                                 cfg.vocab_size)
    decode_step = jax.jit(make_decode_step(model,
                                           temperature=args.temperature))
    prefill = jax.jit(model.prefill)
    total = 0
    t0 = time.time()
    for lo in range(0, args.requests, B):
        batch = prompts[lo:lo + B]
        cache = model.init_cache(batch.shape[0], P + G,
                                 dtype=jnp.float32)
        logits, cache = prefill(params, {"tokens": batch}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        for i in range(G - 1):
            tok, cache, key = decode_step(params, tok, cache,
                                          jnp.int32(P + i), key)
        tok.block_until_ready()
        total += batch.shape[0] * G
    wall = time.time() - t0
    return {"engine": "dense", "tokens": total, "wall_s": round(wall, 3),
            "tokens_per_s": round(total / max(wall, 1e-9), 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "fixed", "dense"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-request KV cap; 0 -> prompt-len + gen-max")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-min", type=int, default=16)
    ap.add_argument("--gen-max", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--attn", default="pallas", choices=["ref", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT_JSON")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="OUT_JSONL")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    key = jax.random.PRNGKey(args.seed)

    if args.engine == "dense":
        print(json.dumps({"arch": cfg.name, **run_dense(model, cfg, args,
                                                        key)}))
        return

    from repro import obs
    from repro.serve import ServeConfig, ServeEngine

    telemetry = None
    if args.trace or args.telemetry_jsonl:
        sinks = []
        if args.telemetry_jsonl:
            sinks.append(obs.JsonlSink(args.telemetry_jsonl))
        telemetry = obs.Telemetry(sinks=sinks, trace_path=args.trace,
                                  run_name="serve")

    max_len = args.max_len or (args.prompt_len + args.gen_max)
    scfg = ServeConfig(
        max_slots=args.max_slots, page_size=args.page_size,
        max_len=max_len, prompt_pad=max(args.prompt_len, 1),
        temperature=args.temperature, kv_int8=args.kv_int8,
        attn=args.attn)
    params = model.init(key)
    engine = ServeEngine(cfg, scfg, params, seed=args.seed)
    reqs = draw_requests(args.requests, args.prompt_len, args.gen_min,
                         args.gen_max, cfg.vocab_size, seed=args.seed)
    results, stats = engine.run(reqs, telemetry=telemetry,
                                continuous=args.engine == "continuous")
    if telemetry is not None:
        telemetry.finish()
    trail = stats.pop("occupancy_trail")
    print(json.dumps({
        "arch": cfg.name, **stats,
        "requests": len(reqs),
        "kv_int8": args.kv_int8,
        "tokens_per_s": round(stats["tokens_per_s"], 1),
        "wall_s": round(stats["wall_s"], 3),
        "mean_occupancy": round(sum(trail) / max(len(trail), 1), 2),
        "sample_tokens": results[0][:16],
    }))


if __name__ == "__main__":
    main()
