import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent for every
(architecture x input shape x mesh) combination with .lower().compile()
on placeholder devices — no allocation, ShapeDtypeStruct inputs only.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --shape train_4k [--multi-pod] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Emits memory_analysis / cost_analysis and the three roofline terms
(EXPERIMENTS.md §Dry-run / §Roofline read from this output).
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (INPUT_SHAPES, FedConfig, TrainConfig)
from repro.configs.registry import ARCHS, ASSIGNED, get_config
from repro.core import pod
from repro.launch import inputs as inputs_lib
from repro.launch import roofline as roof
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.model import build
from repro.optim import optimizers
from repro.sharding import specs as sh


def _named(mesh, spec_tree):
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def lower_train(cfg, shape_name, mesh, variant="baseline"):
    shape = INPUT_SHAPES[shape_name]
    n_dp_groups = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n_dp_groups *= mesh.shape[ax]
    C = min(n_dp_groups, shape.global_batch)
    fed = FedConfig(n_clients=C)
    tc = TrainConfig(global_batch=shape.global_batch, seq_len=shape.seq_len)

    params_s = jax.eval_shape(
        lambda k: transformer.init_transformer(k, cfg), jax.random.PRNGKey(0))
    opt_init, _ = optimizers.make_optimizer(tc)
    state_s = jax.eval_shape(
        lambda p: pod.init_pod_state(p, opt_init, C, fed,
                                     jax.random.PRNGKey(0)), params_s)
    batch_s = inputs_lib.train_batch_specs(cfg, shape_name)

    spec_fn = (sh.param_specs_moe_ff if variant in ("moe_ff", "zero1_moe")
               else sh.param_specs)
    state_sh = _named(mesh, spec_fn(state_s, mesh=mesh))
    batch_sh = _named(mesh, sh.batch_specs(batch_s, mesh))

    zero1 = None
    if variant == "zero1":
        compute_sh = _named(mesh, sh.param_specs_tp(params_s, mesh=mesh))
        master_sh = _named(mesh, sh.param_specs(params_s, mesh=mesh))
        zero1 = (compute_sh, master_sh)
    elif variant == "zero1_moe":
        compute_sh = _named(mesh,
                            sh.param_specs_zero1_moe(params_s, mesh=mesh))
        master_sh = _named(mesh, sh.param_specs_moe_ff(params_s, mesh=mesh))
        zero1 = (compute_sh, master_sh)
    step = pod.make_train_step(cfg, fed, tc, zero1_shardings=zero1)
    with mesh:
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None)).lower(
                              state_s, batch_s)
    return lowered, params_s


def lower_prefill(cfg, shape_name, mesh, variant="baseline"):
    model = build(cfg)
    params_s = jax.eval_shape(
        lambda k: transformer.init_transformer(k, cfg), jax.random.PRNGKey(0))
    params_bf16 = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), params_s)
    batch_s = inputs_lib.infer_batch_specs(cfg, shape_name)
    cache_s = inputs_lib.cache_specs_struct(cfg, shape_name)

    spec_fn = sh.param_specs_tp if variant == "tp_serve" else sh.param_specs
    params_sh = _named(mesh, spec_fn(params_bf16, mesh=mesh))
    batch_sh = _named(mesh, sh.batch_specs(batch_s, mesh))
    cache_sh = _named(mesh, sh.cache_specs(cache_s, mesh))

    with mesh:
        lowered = jax.jit(
            model.prefill,
            in_shardings=(params_sh, batch_sh, cache_sh),
            out_shardings=(None, cache_sh)).lower(params_bf16, batch_s,
                                                  cache_s)
    return lowered, params_s


def lower_decode(cfg, shape_name, mesh, variant="baseline"):
    model = build(cfg)
    shape = INPUT_SHAPES[shape_name]
    params_s = jax.eval_shape(
        lambda k: transformer.init_transformer(k, cfg), jax.random.PRNGKey(0))
    params_bf16 = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16), params_s)
    batch_s = inputs_lib.infer_batch_specs(cfg, shape_name, decode=True)
    cache_s = inputs_lib.cache_specs_struct(cfg, shape_name)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)

    spec_fn = sh.param_specs_tp if variant == "tp_serve" else sh.param_specs
    params_sh = _named(mesh, spec_fn(params_bf16, mesh=mesh))
    batch_sh = _named(mesh, sh.batch_specs(batch_s, mesh))
    cache_sh = _named(mesh, sh.cache_specs(cache_s, mesh))

    with mesh:
        lowered = jax.jit(
            model.decode,
            in_shardings=(params_sh, batch_sh, cache_sh, None),
            out_shardings=(None, cache_sh)).lower(params_bf16, batch_s,
                                                  cache_s, pos_s)
    return lowered, params_s


def _kind_probe_cfg(cfg, block_kind, n_layers_probe):
    """Probe variant: n_layers_probe layers of ONE block kind, unrolled.

    HloCostAnalysis counts a while-loop body ONCE regardless of trip count,
    so the scanned full model under-reports flops/bytes/collectives. The
    dry-run therefore compiles two small UNROLLED probes per distinct
    block kind (1 and 2 layers) and composes

        cost_full = base + sum_kind n_kind * delta_kind,

    where base = 2*cost(kind, 1) - cost(kind, 2) (embed/head/loss/fitness,
    identical across kinds) and delta_kind = cost(kind, 2) - cost(kind, 1).
    Per-kind probing keeps probe graphs tiny even for heterogeneous stacks
    (xLSTM's 8-layer cycle, the VLM's 5-layer cycle) where unrolling whole
    cycles made compiles intractable.
    (Residual known undercount: the sLSTM time-step scan, inherently
    sequential, ~<1% of xlstm flops — documented in EXPERIMENTS.md.)
    """
    return cfg.replace(n_layers=n_layers_probe,
                       block_pattern=(block_kind,) * n_layers_probe,
                       scan_unroll=True)


def _lower_for(cfg, shape_name, mesh, kind, variant="baseline"):
    if kind == "train":
        return lower_train(cfg, shape_name, mesh, variant)
    if kind == "prefill":
        return lower_prefill(cfg, shape_name, mesh, variant)
    return lower_decode(cfg, shape_name, mesh, variant)


def _probe_costs(cfg, shape_name, mesh, kind, variant="baseline"):
    """Composed per-chip flops/bytes/collective-bytes for the full depth,
    from two unrolled shallow probes per distinct block kind."""
    from collections import Counter

    kind_counts = Counter(cfg.layers)

    def one_probe(block_kind, n_layers_probe):
        pcfg = _kind_probe_cfg(cfg, block_kind, n_layers_probe)
        lowered, _ = _lower_for(pcfg, shape_name, mesh, kind, variant)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = roof.parse_collectives(compiled.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    base_f = base_b = None
    base_c = None
    tot_f = tot_b = 0.0
    tot_c = {}
    for bk, n_bk in kind_counts.items():
        f1, b1, c1 = one_probe(bk, 1)
        f2, b2, c2 = one_probe(bk, 2)
        if base_f is None:
            base_f = 2 * f1 - f2
            base_b = 2 * b1 - b2
            base_c = {kk: 2 * c1[kk] - c2[kk] for kk in c1}
        tot_f += n_bk * (f2 - f1)
        tot_b += n_bk * (b2 - b1)
        for kk in c1:
            tot_c[kk] = tot_c.get(kk, 0.0) + n_bk * (c2[kk] - c1[kk])
    flops = max(base_f + tot_f, 0.0)
    byts = max(base_b + tot_b, 0.0)
    coll = {kk: max(base_c.get(kk, 0.0) + v, 0.0) for kk, v in tot_c.items()}
    return {"flops": flops, "bytes accessed": byts}, coll


def run_one(arch: str, shape_name: str, *, multi_pod=False, verbose=True,
            probe=True, variant="baseline"):
    base = get_config(arch)
    cfg = inputs_lib.shape_variant(base, shape_name)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, params_s = _lower_for(cfg, shape_name, mesh, shape.kind,
                                   variant)
    compiled = lowered.compile()
    dt = time.time() - t0

    mem = compiled.memory_analysis()
    if probe:
        cost, coll = _probe_costs(cfg, shape_name, mesh, shape.kind, variant)
    else:
        cost = compiled.cost_analysis()
        coll = roof.parse_collectives(compiled.as_text())
    terms = roof.roofline(cost, coll)
    n_params = roof.count_params(params_s)
    mflops = roof.model_flops(cfg, n_params, shape, shape.kind)
    n_chips = mesh.size
    terms["model_flops_global"] = mflops
    terms["model_flops_per_chip"] = mflops / n_chips
    terms["useful_ratio"] = (mflops / n_chips) / max(terms["hlo_flops"], 1.0)
    result = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "kind": shape.kind,
        "n_params": n_params,
        "compile_s": round(dt, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        **terms,
    }
    if verbose:
        print(json.dumps(result, indent=1, default=float))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None, help="append results as jsonl")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "zero1", "tp_serve"])
    ap.add_argument("--no-probe", action="store_true",
                    help="skip cost probes (lowering proof only; the\n"
                    "multi-pod pass does not feed the roofline table)")
    args = ap.parse_args()

    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED for s in INPUT_SHAPES]
    else:
        archs = [args.arch] if args.arch else ASSIGNED
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
        combos = [(a, s) for a in archs for s in shapes]

    ok, failed = 0, []
    for arch, shape in combos:
        tag = f"{arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'})"
        print(f"==== {tag} ====", flush=True)
        try:
            res = run_one(arch, shape, multi_pod=args.multi_pod,
                          variant=args.variant, probe=not args.no_probe)
            ok += 1
            if args.json:
                with open(args.json, "a") as f:
                    f.write(json.dumps(res, default=float) + "\n")
        except Exception:
            traceback.print_exc()
            failed.append(tag)
    print(f"\nDRY-RUN: {ok}/{len(combos)} combinations compiled")
    if failed:
        print("FAILED:", *failed, sep="\n  ")
        sys.exit(1)


if __name__ == "__main__":
    main()
