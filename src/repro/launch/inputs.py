"""ShapeDtypeStruct stand-ins for every model input x input-shape
(no device allocation — the dry-run contract).

``input_specs(cfg, shape)`` returns the batch pytree for train/prefill;
decode shapes additionally need the cache/pos structs from
``decode_specs``. [audio]/[vlm] frontends are STUBS: precomputed frame /
patch embeddings of the right shape (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig

SDS = jax.ShapeDtypeStruct


def shape_variant(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Per-shape config adjustments.

    long_500k requires sub-quadratic attention: SSM/hybrid archs are
    natively O(1)-state; every full-attention arch switches to its
    sliding-window variant (window 8192, ring cache) for this shape —
    nothing is skipped (DESIGN.md §4).
    """
    shape = INPUT_SHAPES[shape_name]
    kw = {}
    if shape.kind == "train":
        # chunk the LM-head loss: full (B,S,V) logits at vocab 152k would
        # dominate activation memory
        kw["loss_chunk"] = 512
    if shape_name == "long_500k" and cfg.arch_type not in ("ssm",):
        if not cfg.sliding_window:
            kw["sliding_window"] = 8192
    return cfg.replace(**kw) if kw else cfg


def train_batch_specs(cfg: ModelConfig, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    gb, s = shape.global_batch, shape.seq_len
    batch = {"targets": SDS((gb, s), jnp.int32)}
    if cfg.embed_inputs:
        batch["tokens"] = SDS((gb, s), jnp.int32)
    else:
        batch["embeds"] = SDS((gb, s, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = SDS((gb, cfg.n_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def infer_batch_specs(cfg: ModelConfig, shape_name: str, *, decode=False):
    shape = INPUT_SHAPES[shape_name]
    gb = shape.global_batch
    s = 1 if decode else shape.seq_len
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = SDS((gb, s), jnp.int32)
    else:
        batch["embeds"] = SDS((gb, s, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "vlm" and not decode:
        batch["image_embeds"] = SDS((gb, cfg.n_image_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return batch


def batch_shardings(batch, mesh):
    """``NamedSharding`` tree for a train batch pytree (arrays OR
    ShapeDtypeStructs): the leading global-batch dim shards over the
    pod+data mesh axes.  This is the sharding the pod scan driver's
    prefetch stages chunk batches onto (``core/driver.py`` /
    ``pod.run(batch_sharding=...)``), so chunk k+1's host->device
    transfer lands directly on the pod shards while chunk k computes."""
    from repro.sharding import specs as sh

    return sh.named(mesh, sh.batch_specs(batch, mesh))


def cache_specs_struct(cfg: ModelConfig, shape_name: str):
    """Abstract cache pytree (eval_shape over init_cache)."""
    from repro.models import transformer

    shape = INPUT_SHAPES[shape_name]
    ring = bool(cfg.sliding_window) and shape_name == "long_500k"
    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch,
                                       shape.seq_len, ring=ring,
                                       dtype=jnp.bfloat16))
