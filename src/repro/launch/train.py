"""End-to-end federated training driver (runs on whatever devices exist).

Runs the PodEngine: FedFiTS client groups on the mesh data axis, one SPMD
program per round. With the default tiny-lm config this trains a ~100M
decoder on synthetic non-IID LM data on CPU; on a pod the same script
scales to the assigned architectures via --arch.

  PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 50 \
      --global-batch 16 --seq 256 --clients 4 [--ckpt-dir /tmp/ck]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import FedConfig, TrainConfig
from repro.configs.registry import get_config
from repro.core import pod
from repro.data import synthetic
from repro.launch import inputs
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.optim import optimizers
from repro.sharding import specs as sh


def synthetic_lm_batches(cfg, tc, n_clients, seed):
    """Per-client non-IID LM streams: each client group draws from its own
    latent Markov mixture component (label-skew analogue for LM data)."""
    key = jax.random.PRNGKey(seed)
    per = 64  # sequences per client pool
    pools = []
    for c in range(n_clients):
        toks = synthetic.make_lm_tokens(
            jax.random.fold_in(key, c), per, tc.seq_len + 1,
            cfg.vocab_size, n_latent=2)
        pools.append(np.asarray(toks))
    pools = jnp.asarray(np.stack(pools))        # (C, per, S+1)

    def sample(step_rng):
        bc = tc.global_batch // n_clients
        idx = jax.random.randint(step_rng, (n_clients, bc), 0, per)
        seqs = jax.vmap(lambda p, i: p[i])(pools, idx)  # (C, bc, S+1)
        seqs = seqs.reshape(tc.global_batch, tc.seq_len + 1)
        return {"tokens": seqs[:, :-1], "targets": seqs[:, 1:]}

    return jax.jit(sample)


def make_telemetry(args, run_name="run"):
    """--trace/--telemetry-jsonl/--profile-dir -> a Telemetry (or None
    when no obs output was requested; the scenario path still attaches
    its default in-memory telemetry in that case)."""
    from repro import obs

    sinks = []
    if args.telemetry_jsonl:
        sinks.append(obs.JsonlSink(args.telemetry_jsonl))
    if not (args.telemetry_jsonl or args.trace or args.profile_dir):
        return None
    return obs.Telemetry(sinks=sinks, trace_path=args.trace,
                         profiler_dir=args.profile_dir, run_name=run_name)


def run_scenario_cli(args):
    """--scenario: one robustness-registry cell through the SimEngine."""
    from repro.scenarios import run_scenario

    rounds = min(args.steps, 50)        # SimEngine rounds, not LM steps
    telemetry = make_telemetry(args, run_name=args.scenario)
    ctx = telemetry.profiled() if telemetry is not None else None
    if ctx is not None:
        ctx.__enter__()
    try:
        summary, hist = run_scenario(
            args.scenario, n_clients=args.clients, n_rounds=rounds,
            driver=args.driver, chunk_rounds=args.chunk_rounds,
            population=args.population, async_deadline=args.async_deadline,
            telemetry=telemetry)
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    for h in hist:
        print(json.dumps({
            "round": int(h["round"]),
            "test_acc": round(float(h["test_acc"]), 4),
            "trigger_acc": round(float(h["trigger_acc"]), 4),
            "fair_worst_decile": round(float(h["fair_worst_decile"]), 4),
            "fair_part_gini": round(float(h["fair_part_gini"]), 4),
            "gated_frac": round(float(h["gated_frac"]), 4),
        }))
    print(json.dumps(summary))


def main():
    ap = argparse.ArgumentParser(
        epilog="The jittable entry points behind these flags (round "
               "engines, aggregation kernels, codecs, decode step) are "
               "statically audited — copy/RNG/donation/dtype/collective/"
               "VMEM invariants — by `python -m repro.analysis.lint "
               "--all` (see `--list` there for the entry registry); CI "
               "gates on it.")
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test reduced arch variant")
    ap.add_argument("--robust", default=None, choices=[None, "per_client"],
                    help="per_client: coordinate-robust aggregation over "
                         "per-client grads, mesh-sharded along the "
                         "flattened param axis")
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "int4", "signsgd", "topk"],
                    help="client->server transport codec (repro/comm/): "
                         "per-client grads cross the boundary encoded, "
                         "with EF residuals in the scan carry; int8 "
                         "aggregates straight from the wire codes "
                         "(fused dequant). Requires --robust per_client")
    ap.add_argument("--driver", default="scan", choices=["scan", "python"],
                    help="scan: chunked lax.scan rounds (donated carry, "
                         "sharding-aware batch prefetch); python: the "
                         "per-round jit loop (parity oracle)")
    ap.add_argument("--chunk-rounds", type=int, default=8)
    ap.add_argument("--scenario", default=None,
                    help="run a named robustness scenario (attack x "
                         "heterogeneity x compression x aggregator cell "
                         "from repro.scenarios.registry — e.g. "
                         "alie_fedavg, gate_aware_trimmed, "
                         "gate_aware_int8_dropout) through the SimEngine "
                         "instead of the pod LM trainer; --steps sets the "
                         "round count and --clients the cohort size. "
                         "Prints per-round accuracy/trigger-accuracy/"
                         "fairness rows and the robustness summary")
    ap.add_argument("--population", type=int, default=None,
                    help="register this many clients in the population-"
                         "scale ClientStore and route the --scenario run "
                         "through the buffered-async engine "
                         "(core/async_engine.py): each round samples a "
                         "--clients-sized cohort by O(M) Gumbel-top-d "
                         "over the store's fitness x trust priority; "
                         "late deliveries retry through the staleness-"
                         "weighted buffer. Only meaningful with "
                         "--scenario")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="write a Chrome/Perfetto trace-event JSON for "
                         "the run (repro/obs/trace.py): measured driver "
                         "spans at chunk granularity plus attributed "
                         "per-round phase spans carrying each round's "
                         "counter values. Load in ui.perfetto.dev; "
                         "validate with python -m repro.obs.check")
    ap.add_argument("--telemetry-jsonl", default=None, metavar="OUT_JSONL",
                    help="stream the obs metric rows + drift-monitor "
                         "warnings as JSON lines (one record per round; "
                         "kind=metrics|warning|summary)")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the run in jax.profiler.trace(DIR) — the "
                         "ground-truth XLA timeline escape hatch (view "
                         "with TensorBoard/Perfetto)")
    ap.add_argument("--async-deadline", type=float, default=None,
                    help="per-round delivery deadline of the buffered-"
                         "async engine (the exponential client delays "
                         "race it; FedConfig.async_deadline). Forces the "
                         "--scenario cell through the async engine, like "
                         "--population")
    args = ap.parse_args()

    if (args.population or args.async_deadline) and not args.scenario:
        ap.error("--population/--async-deadline drive the buffered-async "
                 "SimEngine and need --scenario (e.g. "
                 "--scenario async_hetero)")
    if (args.population or args.async_deadline) and args.scenario:
        from repro.scenarios import registry as scen_registry
        try:
            sc = scen_registry.get(args.scenario)
        except Exception:
            sc = None                 # unknown name: run_scenario reports it
        if sc is not None and sc.compress != "none":
            ap.error(f"--scenario {args.scenario} is a compressed-uplink "
                     f"cell (compress={sc.compress}), but the buffered-"
                     "async engine (--population/--async-deadline) is "
                     "dense-uplink only — drop those flags to run the "
                     "cell on the sync engine, or pick a dense cell "
                     "(e.g. async_hetero)")

    if args.scenario:
        run_scenario_cli(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.compress != "none" and args.robust != "per_client":
        ap.error("--compress needs --robust per_client (only that path "
                 "moves per-client updates across the wire)")
    fed = FedConfig(n_clients=args.clients, compress=args.compress)
    tc = TrainConfig(global_batch=args.global_batch, seq_len=args.seq,
                     lr=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1))

    mesh = make_host_mesh(args.data_axis, args.model_axis)
    key = jax.random.PRNGKey(tc.seed)
    params = transformer.init_transformer(key, cfg)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, fed.n_clients, fed, key)

    state_sh = sh.named(mesh, sh.param_specs(state, mesh=mesh))
    state = jax.device_put(state, state_sh)
    # the scan driver donates the carry: params/opt-state update in
    # place, no per-step copy (sharding follows the committed state)
    step_fn = pod.make_train_step(cfg, fed, tc, robust=args.robust,
                                  agg_mesh=mesh if args.robust else None)

    start = 0
    if args.ckpt_dir:
        restored, at = ckpt.restore_latest(args.ckpt_dir, state, state_sh)
        if restored is not None:
            state, start = restored, at
            print(f"restored checkpoint at step {at}")

    # scan-driver checkpoints happen at chunk ends (mid-chunk states never
    # exist host-side): align the chunk size to the checkpoint cadence so
    # a crash loses at most ckpt_every-1 steps, like the python driver
    chunk_rounds = args.chunk_rounds
    if args.ckpt_dir and args.driver == "scan":
        chunk_rounds = min(chunk_rounds, args.ckpt_every)
        if args.ckpt_every % chunk_rounds:
            print(f"# note: ckpt-every {args.ckpt_every} not divisible by "
                  f"chunk-rounds {chunk_rounds}; saves land on the first "
                  f"chunk end at/after each due step")

    sampler = synthetic_lm_batches(cfg, tc, fed.n_clients, tc.seed)
    # the donated carry aliases `key` (PodFedState.rng) and deletes its
    # buffer on the first chunk; sample from a live copy
    sample_key = jnp.array(np.asarray(key))
    # sharding-aware prefetch: stage each chunk's batches directly onto
    # their pod shards while the previous chunk computes
    batch_sh = inputs.batch_shardings(
        jax.eval_shape(sampler, jax.random.PRNGKey(0)), mesh)
    t0 = time.time()

    def on_chunk(st, rows):
        for row in rows:
            step = row["step"]
            if step % 5 == 0 or step == args.steps - 1:
                m = {k: round(float(v), 4) for k, v in row.items()
                     if k != "step"}
                m["step"] = step
                m["wall_s"] = round(time.time() - t0, 1)
                print(json.dumps(m))
        last = rows[-1]["step"]
        if args.ckpt_dir and any((r["step"] + 1) % args.ckpt_every == 0
                                 for r in rows):
            ckpt.save_step(args.ckpt_dir, last + 1, st)

    telemetry = make_telemetry(args, run_name=args.arch)
    with mesh:
        if telemetry is not None:
            with telemetry.profiled():
                state, _ = pod.run(
                    state, step_fn, lambda t: sampler(jax.random.fold_in(
                        sample_key, t)),
                    args.steps - start, driver=args.driver,
                    chunk_rounds=chunk_rounds, batch_sharding=batch_sh,
                    t0=start, on_chunk=on_chunk, telemetry=telemetry)
            telemetry.finish()
        else:
            state, _ = pod.run(
                state, step_fn, lambda t: sampler(jax.random.fold_in(
                    sample_key, t)),
                args.steps - start, driver=args.driver,
                chunk_rounds=chunk_rounds, batch_sharding=batch_sh,
                t0=start, on_chunk=on_chunk)
    print("done")


if __name__ == "__main__":
    main()
