"""Production mesh construction (v5e pods).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any
device initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
