"""Roofline-term derivation from compiled dry-run artifacts (no real TPU).

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = sum(collective operand bytes x ring factor) / ICI_bw

cost_analysis() runs on the *partitioned* module, so its flops/bytes are
already per-chip. Collective bytes are parsed from the partitioned HLO
text (per-chip shapes); all-reduce gets a 2x ring factor (reduce-scatter +
all-gather phases), others 1x. '-done' halves of async pairs are skipped
to avoid double counting.
"""
from __future__ import annotations

import re
from typing import Dict

from repro.configs.base import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_SHAPE_RE = re.compile(r"(pred|[fsu]\d+|bf16|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Per-chip bytes by collective kind from partitioned HLO text."""
    out = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = re.search(r"=\s*(.*?)\s(" + "|".join(_COLL) + r")(-start)?\(",
                      line)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
    return out


def roofline(cost: dict, coll_bytes: Dict[str, int]) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = sum(v * (2 if k == "all-reduce" else 1)
                 for k, v in coll_bytes.items())
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = cbytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {
        **terms,
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": cbytes,
        "collective_by_kind": dict(coll_bytes),
        "dominant": dom,
        "bound_s": max(terms.values()),
    }


def count_params(params_struct) -> int:
    import jax

    return sum(int(_prod(l.shape)) for l in
               jax.tree_util.tree_leaves(params_struct))


def active_params(cfg, n_params: int) -> int:
    """6*N_active*D MoE correction: expert FFN weights scale by top_k/E."""
    if not cfg.n_experts:
        return n_params
    cycle_moe = sum(1 for k in cfg.layers if k == "moe")
    expert_w = cycle_moe * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
    return n_params - expert_w + expert_w * cfg.top_k // cfg.n_experts


def model_flops(cfg, n_params: int, shape, kind: str) -> float:
    """6*N*D (train) / 2*N*D (inference forward) reference FLOPs, global."""
    n_act = active_params(cfg, n_params)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def _prod(t):
    r = 1
    for x in t:
        r *= x
    return r
