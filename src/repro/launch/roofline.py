"""Roofline-term derivation from compiled dry-run artifacts (no real TPU).

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = sum(collective operand bytes x ring factor) / ICI_bw

cost_analysis() runs on the *partitioned* module, so its flops/bytes are
already per-chip. Collective bytes are parsed from the partitioned HLO
text (per-chip shapes); all-reduce gets a 2x ring factor (reduce-scatter +
all-gather phases), others 1x. '-done' halves of async pairs are skipped
to avoid double counting.
"""
from __future__ import annotations

from typing import Dict

# HLO-text parsing lives in the shared analysis helpers; the roofline and
# the invariant linter (repro/analysis/rules.py) read the same parser.
from repro.analysis.hlo import (COLLECTIVES as _COLL,  # noqa: F401
                                DTYPE_BYTES as _DTYPE_BYTES,
                                SHAPE_RE as _SHAPE_RE,
                                parse_collectives,
                                shape_bytes as _shape_bytes)
from repro.configs.base import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def roofline(cost: dict, coll_bytes: Dict[str, int]) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = sum(v * (2 if k == "all-reduce" else 1)
                 for k, v in coll_bytes.items())
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = cbytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    return {
        **terms,
        "hlo_flops": flops,
        "hlo_bytes": byts,
        "collective_bytes": cbytes,
        "collective_by_kind": dict(coll_bytes),
        "dominant": dom,
        "bound_s": max(terms.values()),
    }


def measured_wire_bytes(rows) -> dict:
    """Aggregate the MEASURED ``wire/bytes_up``/``wire/bytes_down``
    telemetry gauges so they can sit next to the modeled roofline terms.

    ``rows`` is either a list of drained metric rows (dicts with
    ``obs/wire/...`` keys) or a path to a telemetry JSONL stream
    (``kind == "metrics"`` records are used).  Returns totals and
    per-round means; ``rounds`` is the number of rows that carried the
    gauges (0 when telemetry counters were off)."""
    if isinstance(rows, str):
        import json
        with open(rows) as f:
            rows = [r for r in (json.loads(l) for l in f if l.strip())
                    if r.get("kind") == "metrics"]
    up = [float(r["obs/wire/bytes_up"]) for r in rows
          if "obs/wire/bytes_up" in r]
    down = [float(r["obs/wire/bytes_down"]) for r in rows
            if "obs/wire/bytes_down" in r]
    n = max(len(up), len(down))
    return {
        "rounds": n,
        "bytes_up": sum(up),
        "bytes_down": sum(down),
        "bytes_up_per_round": sum(up) / n if n else 0.0,
        "bytes_down_per_round": sum(down) / n if n else 0.0,
    }


def count_params(params_struct) -> int:
    import jax

    return sum(int(_prod(l.shape)) for l in
               jax.tree_util.tree_leaves(params_struct))


def active_params(cfg, n_params: int) -> int:
    """6*N_active*D MoE correction: expert FFN weights scale by top_k/E."""
    if not cfg.n_experts:
        return n_params
    cycle_moe = sum(1 for k in cfg.layers if k == "moe")
    expert_w = cycle_moe * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff
    return n_params - expert_w + expert_w * cfg.top_k // cfg.n_experts


def model_flops(cfg, n_params: int, shape, kind: str) -> float:
    """6*N*D (train) / 2*N*D (inference forward) reference FLOPs, global."""
    n_act = active_params(cfg, n_params)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def _prod(t):
    r = 1
    for x in t:
        r *= x
    return r
