from repro.launch import dryrun  # noqa: F401  (sets XLA_FLAGS first)

"""Perf-iteration driver (§Perf): measures roofline terms for optimisation
variants of the three hillclimbed (arch x shape) pairs, probe-only (the
full lowering proof for each accepted variant is run separately).

  PYTHONPATH=src python -m repro.launch.perf --pair qwen --variant zero1
"""

import argparse
import json

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config
from repro.launch import inputs as inputs_lib
from repro.launch import roofline as roof
from repro.launch.dryrun import _probe_costs
from repro.launch.mesh import make_production_mesh

PAIRS = {
    "qwen": ("qwen2.5-14b", "train_4k"),
    "dbrx": ("dbrx-132b", "train_4k"),
    "hymba": ("hymba-1.5b", "train_4k"),
}

# variant name -> (cfg override dict, lowering variant)
VARIANTS = {
    "baseline": ({}, "baseline"),
    "zero1": ({}, "zero1"),
    "moe_ff": ({}, "moe_ff"),
    "moe_ff_cap1": ({"capacity_factor": 1.0}, "moe_ff"),
    "zero1_moe": ({}, "zero1_moe"),
    "zero1_cap1": ({"capacity_factor": 1.0}, "zero1"),
    "noremat": ({"remat": False}, "baseline"),
    "zero1_noremat": ({"remat": False}, "zero1"),
    "bf16scan": ({"ssm_scan_dtype": "bfloat16"}, "baseline"),
    "zero1_bf16scan": ({"ssm_scan_dtype": "bfloat16"}, "zero1"),
    "zero1_bf16scan_noremat": (
        {"ssm_scan_dtype": "bfloat16", "remat": False}, "zero1"),
    "chunk512": ({"scan_chunk": 512}, "baseline"),
}


def measure(pair, variant_name, json_path=None):
    arch, shape_name = PAIRS[pair]
    overrides, lower_variant = VARIANTS[variant_name]
    cfg = inputs_lib.shape_variant(get_config(arch), shape_name)
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = make_production_mesh()
    shape = INPUT_SHAPES[shape_name]
    cost, coll = _probe_costs(cfg, shape_name, mesh, shape.kind,
                              lower_variant)
    terms = roof.roofline(cost, coll)
    res = {"pair": pair, "arch": arch, "shape": shape_name,
           "variant": variant_name, **terms}
    print(json.dumps(res, indent=1, default=float))
    if json_path:
        with open(json_path, "a") as f:
            f.write(json.dumps(res, default=float) + "\n")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(PAIRS))
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--json", default="results/perf_iters.jsonl")
    args = ap.parse_args()
    measure(args.pair, args.variant, args.json)


if __name__ == "__main__":
    main()
