"""Quickstart: FedFiTS in ~40 lines.

Trains the paper's MLP on synthetic non-IID tabular data with fitness-
selected, slotted client scheduling, and prints the per-round team.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build

K = 8
model = build(ARCHS["paper-mlp"])
federation, server_test = build_federation(
    seed=0, kind="tabular", n=1600, n_clients=K, batch_size=32,
    n_classes=22, dirichlet_alpha=0.3)


@jax.jit
def evaluate(params):
    loss, m = model.loss(params, server_test)
    return {"test_loss": loss, "test_acc": m["acc"]}


fed_cfg = FedConfig(
    n_clients=K,
    algorithm="fedfits",
    alpha=0.5, dynamic_alpha=True,      # Eq. 2 / SSV
    beta=0.1,                           # Eq. 3 threshold openness
    msl=4, pft=2,                       # slotted scheduling (Eqs. 4-5)
    local_epochs=2, local_lr=0.05,
)

state, history = fedfits.run(
    model, fed_cfg, federation.data_fn, n_rounds=15,
    rng=jax.random.PRNGKey(0), eval_fn=evaluate)

for h in history:
    team = "".join("#" if x else "." for x in h["team"])
    print(f"round {h['round']:>2}  team[{team}]  "
          f"alpha={float(h['alpha']):.2f}  "
          f"test_acc={float(h['test_acc']):.3f}")
print(f"\nfinal test accuracy: {float(history[-1]['test_acc']):.3f}")
print(f"billed client-rounds: {float(state.cost_client_rounds):.0f} "
      f"(FedAvg would bill {15 * K})")
