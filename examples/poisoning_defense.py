"""Model-poisoning defense A/B: sign-flip byzantine clients vs the
aggregator zoo (FedAvg mean, coordinate median, trimmed mean, Krum) and
the Pallas robust-aggregation kernel on the same updates — plus the
compressed-transport walkthrough: the same attack under the int8 uplink
codec (repro/comm/), where the server aggregates STRAIGHT from the wire
codes (fused dequant) and bills the measured encoded bytes.

  PYTHONPATH=src python examples/poisoning_defense.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import attacks, fedfits
from repro.data.pipeline import build_federation
from repro.kernels.robust_agg_ops import robust_aggregate_tree
from repro.models.model import build

K, ROUNDS, N_MAL = 10, 12, 2

model = build(ARCHS["paper-mlp"])
federation, server_test = build_federation(
    seed=0, kind="tabular", n=1600, n_clients=K, batch_size=32, n_classes=22)
malicious = jnp.zeros((K,)).at[jnp.arange(N_MAL)].set(1.0)


def update_attack(upd, mal, rng):
    return attacks.sign_flip(upd, mal, scale=10.0)


@jax.jit
def evaluate(params):
    loss, m = model.loss(params, server_test)
    return {"test_acc": m["acc"]}


print(f"{N_MAL}/{K} byzantine clients (10x sign-flipped updates)\n")
for agg in ["fedavg", "median", "trimmed_mean", "krum"]:
    cfg = FedConfig(n_clients=K, algorithm="fedfits", aggregator=agg,
                    local_epochs=2, local_lr=0.05,
                    cosine_outlier_thresh=-0.5)
    state, hist = fedfits.run(model, cfg, federation.data_fn, ROUNDS,
                              jax.random.PRNGKey(2), eval_fn=evaluate,
                              update_attack=update_attack,
                              malicious=malicious)
    accs = [float(h["test_acc"]) for h in hist]
    print(f"aggregator={agg:12s} best_acc={max(accs):.3f} "
          f"final={accs[-1]:.3f}")

# ---- defense under the compressed uplink (repro/comm/) -----------------
# sign-flip attackers + int8 transport: the trimmed-mean defense must
# keep working on the WIRE CODES — the cosine gate and rank network run
# inside the fused dequant kernels, never materialising dense per-client
# updates on the server.  Bytes below are MEASURED from the encoded
# arrays (codes + per-block scales), not an analytic 4-bytes-per-param.
print("\nsign-flip attackers under the compressed uplink "
      "(trimmed_mean defense):")
for comp in ["none", "int8"]:
    cfg = FedConfig(n_clients=K, algorithm="fedfits",
                    aggregator="trimmed_mean", local_epochs=2,
                    local_lr=0.05, cosine_outlier_thresh=-0.5,
                    compress=comp)
    state, hist = fedfits.run(model, cfg, federation.data_fn, ROUNDS,
                              jax.random.PRNGKey(2), eval_fn=evaluate,
                              update_attack=update_attack,
                              malicious=malicious)
    accs = [float(h["test_acc"]) for h in hist]
    print(f"compress={comp:5s} best_acc={max(accs):.3f} "
          f"uplink={float(state.cost_bytes_up) / 1e6:6.2f} MB "
          f"downlink={float(state.cost_bytes_down) / 1e6:.2f} MB "
          f"(client-rounds {float(state.cost_client_rounds):.0f})")

# ---- named cells from the robustness scenario registry -----------------
# the scenario engine (repro/scenarios/) runs curated attack x fault x
# compression x aggregator cells through the exact same round loop and
# reports fairness (worst-decile accuracy, per-client accuracy variance,
# participation Gini) and backdoor trigger accuracy next to plain
# accuracy — trigger accuracy is tracked for EVERY cell; for
# non-backdoor cells it sits at the target-class base rate, which is the
# regression signal
from repro.scenarios import run_scenario

print("\nscenario registry cells (fairness + trigger-accuracy table):")
print(f"{'cell':20s} {'best':>6s} {'final':>6s} {'trig':>6s} "
      f"{'worst10%':>8s} {'acc_var':>8s} {'gini':>5s} {'gated':>6s}")
for cell in ["clean_trimmed", "alie_fedavg", "alie_trimmed",
             "gate_aware_trimmed", "backdoor_trimmed", "dropout_trimmed"]:
    s, _ = run_scenario(cell, n_clients=K, n_rounds=8, n=800)
    print(f"{cell:20s} {s['best_acc']:6.3f} {s['final_acc']:6.3f} "
          f"{s['final_trigger_acc']:6.3f} {s['fair_worst_decile']:8.3f} "
          f"{s['fair_acc_var']:8.4f} {s['fair_part_gini']:5.2f} "
          f"{s['gated_frac_mean']:6.2f}")

# ---- the Pallas kernel on one poisoned round of updates ----------------
key = jax.random.PRNGKey(3)
honest = {"w": jax.random.normal(key, (K, 512)) * 0.01 + 1.0}
poisoned = attacks.sign_flip(honest, malicious, scale=10.0)
for mode in ["trimmed", "median"]:
    out = robust_aggregate_tree(poisoned, jnp.ones((K,)), mode=mode)
    print(f"pallas robust_agg[{mode}] mean coordinate "
          f"= {float(np.mean(np.asarray(out['w']))):.3f} "
          f"(honest value 1.0; naive mean "
          f"{float(np.mean(np.asarray(poisoned['w']))):.3f})")
