"""Continuous-batching serving walkthrough (ROADMAP item 3): the paged
KV serving engine (repro/serve) against the fixed-batch scheduler on a
mixed-length workload.

A fleet of ``max_slots`` decode slots shares one page pool; requests
with log-uniform generation budgets are admitted the moment a slot and
pages free up (continuous) or only when the whole fleet drains (fixed —
the classic batch-until-the-slowest-finishes loop).  Both run the SAME
compiled admit/decode programs, so the tokens/s gap is pure scheduling:
short requests stop hiding behind long ones.

The slot-occupancy trail makes the difference visible: continuous stays
near max_slots the whole run, fixed saws down to 1 while each batch
waits for its longest member.  The int8 leg re-runs continuous with
quantized page pools and prints the per-step KV bytes each decode
streams (codes + per-row scales vs f32 values).

  PYTHONPATH=src python examples/continuous_serving.py
"""
import jax

from repro.configs.registry import get_config
from repro.launch.serve import draw_requests
from repro.models.model import build
from repro.serve import ServeConfig, ServeEngine, kv_bytes_read

REQUESTS, SLOTS, PROMPT = 14, 4, 8
GEN_MIN, GEN_MAX = 8, 64

cfg = get_config("tiny-lm").reduced()
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
reqs = draw_requests(REQUESTS, PROMPT, GEN_MIN, GEN_MAX,
                     cfg.vocab_size, seed=7)
print(f"{REQUESTS} requests, generation budgets "
      f"{sorted(r.max_new for r in reqs)}\n")


def trail_ascii(trail, slots, width=72):
    """One char per decode step (downsampled): occupancy 0..slots."""
    if len(trail) > width:
        hop = len(trail) / width
        trail = [trail[int(i * hop)] for i in range(width)]
    glyphs = " .:-=+*#"
    scale = (len(glyphs) - 1) / max(slots, 1)
    return "".join(glyphs[int(round(v * scale))] for v in trail)


results = {}
for mode, kv_int8 in [("fixed", False), ("continuous", False),
                      ("continuous+int8kv", True)]:
    scfg = ServeConfig(max_slots=SLOTS, page_size=8,
                       max_len=PROMPT + GEN_MAX, prompt_pad=PROMPT,
                       kv_int8=kv_int8, attn="ref")
    engine = ServeEngine(cfg, scfg, params, seed=0)
    engine.run(reqs[:2])        # untimed compile pass
    toks, stats = engine.run(reqs,
                             continuous=mode.startswith("continuous"))
    trail = stats["occupancy_trail"]
    occ = sum(trail) / max(len(trail), 1)
    kv = kv_bytes_read(cfg, scfg, occ * scfg.pages_per_slot)
    results[mode] = (toks, stats)
    print(f"{mode}")
    print(f"  steps={stats['steps']} tokens={stats['tokens']} "
          f"tokens/s={stats['tokens_per_s']:.1f} "
          f"mean occupancy={occ:.2f}/{SLOTS} "
          f"KV bytes/step~{kv / 1e3:.0f}KB")
    print(f"  occupancy trail |{trail_ascii(trail, SLOTS)}|\n")

assert results["fixed"][0] == results["continuous"][0], \
    "argmax decoding: scheduling must not change any request's tokens"
speed = (results["continuous"][1]["tokens_per_s"]
         / results["fixed"][1]["tokens_per_s"])
print(f"continuous vs fixed: {speed:.2f}x tokens/s, identical tokens "
      "per request (scheduling is the only variable)")
