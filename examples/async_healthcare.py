"""Buffered-async healthcare walkthrough (ROADMAP item 1): a regional
network registers 60 clinics but only 12 report per round, and an
increasing share of them are chronic stragglers (rural links, shared
imaging workstations).  The synchronous engine must either wait for the
slowest clinic or drop its work; the buffered-async engine samples a
cohort by fitness x trust (O(M) Gumbel-top-d over the ClientStore),
races each delivery against a round deadline, parks the late ones in a
staleness-weighted retry buffer, and routes around clinics that keep
timing out — so accuracy degrades GRACEFULLY as the straggler rate
climbs.

Sweeps the straggler rate over {0%, 15%, 30%, 45%} and prints best/final
accuracy, on-time fraction, buffered deliveries and abandoned work for
the async engine, against the fault-free synchronous baseline.

Telemetry walkthrough: each sweep cell runs with an on-device counter
column riding the scan carry (repro.obs) draining into a MemorySink —
afterwards the buffer-occupancy trail and the cohort trust p50 show HOW
the engine degraded (deliveries parking in the retry buffer, scheduler
trust routing around chronic stragglers), and the default drift
monitors turn sustained buffer pressure into structured warnings.
Numerics are bit-identical with telemetry on or off.

  PYTHONPATH=src python examples/async_healthcare.py
"""
import jax

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import async_engine, fedfits
from repro.core.faults import FaultConfig
from repro.data.pipeline import build_federation
from repro.models.model import build
from repro.obs import MemorySink, Telemetry
from repro.obs import counters as obs_counters

M, C, ROUNDS = 60, 12, 12       # registered clinics, cohort, rounds


def build_example(m=M, c=C, *, n=3000, batch_size=32, seed=0):
    """Model + M-clinic federation + async config for the walkthrough."""
    model = build(ARCHS["paper-mlp"])
    federation, server_test = build_federation(
        seed=seed, kind="tabular", n=n, n_clients=m, batch_size=batch_size,
        n_classes=10, sep=1.0, dirichlet_alpha=1.0)
    cfg = FedConfig(n_clients=c, population=m, algorithm="fedavg",
                    aggregator="trimmed_mean", local_epochs=2,
                    local_lr=0.2, async_deadline=1.0, async_max_retries=2,
                    async_backoff=1.5, staleness_decay=0.5)
    return model, cfg, federation, server_test


def make_telemetry_round(m=12, c=4, *, n=360, batch_size=8):
    """Small-scale async round body with the telemetry counter column
    attached to the carry — the analysis linter traces this
    (entry ``examples.async_healthcare.round``) to prove the obs column
    keeps the donated carry alias-clean."""
    model, cfg, federation, _ = build_example(
        m, c, n=n, batch_size=batch_size)
    r_init, r_run = jax.random.split(jax.random.PRNGKey(0))
    state = async_engine.init_async_state(model.init(r_init), cfg, r_run)
    state = state._replace(tele=obs_counters.init_column("async", cfg))
    round_fn = async_engine.make_async_round(model, cfg, federation.data,
                                             batch_size=batch_size)
    return round_fn, state


def run_sync_baseline(model, rounds=ROUNDS, *, c=C, n=3000):
    """Fault-free synchronous reference: a C-clinic federation where
    everyone always answers (the best case async is measured against)."""
    sync_fed, sync_test = build_federation(
        seed=0, kind="tabular", n=n, n_clients=c, batch_size=32,
        n_classes=10, sep=1.0, dirichlet_alpha=1.0)
    sync_cfg = FedConfig(n_clients=c, algorithm="fedavg",
                         aggregator="trimmed_mean", local_epochs=2,
                         local_lr=0.2)

    @jax.jit
    def evaluate(params):
        _, met = model.loss(params, sync_test)
        return {"test_acc": met["acc"]}

    _, hist = fedfits.run(model, sync_cfg, sync_fed.data_fn, rounds,
                          jax.random.PRNGKey(1), eval_fn=evaluate)
    return max(float(h["test_acc"]) for h in hist)


def run_straggler_cell(model, cfg, federation, evaluate, frac,
                       rounds=ROUNDS):
    """One sweep cell with its own telemetry: counters ride the carry,
    metrics land in a MemorySink, the default monitors watch for drift."""
    faults = FaultConfig(straggler_frac=frac, straggler_delay=3.0,
                         base_delay=0.3) if frac else FaultConfig()
    sink = MemorySink()
    telemetry = Telemetry(sinks=[sink], run_name=f"stragglers_{frac:.0%}")
    state, hist = async_engine.run_async(
        model, cfg, federation.data, rounds, jax.random.PRNGKey(1),
        eval_fn=evaluate, batch_size=32, faults=faults,
        telemetry=telemetry)
    telemetry.finish()
    return state, hist, sink


def main():
    model, cfg, federation, server_test = build_example()

    @jax.jit
    def evaluate(params):
        _, met = model.loss(params, server_test)
        return {"test_acc": met["acc"]}

    sync_best = run_sync_baseline(model)
    print(f"{M} registered clinics, cohort {C}/round, {ROUNDS} rounds")
    print(f"synchronous fault-free baseline: best_acc={sync_best:.3f}\n")
    print(f"{'stragglers':>10s} {'best_acc':>8s} {'final':>6s} "
          f"{'on_time':>7s} {'buffered':>8s} {'abandoned':>9s} "
          f"{'warnings':>8s}")

    state = None
    trails = []
    for frac in (0.0, 0.15, 0.30, 0.45):
        state, hist, sink = run_straggler_cell(
            model, cfg, federation, evaluate, frac)
        accs = [float(h["test_acc"]) for h in hist]
        on_time = sum(float(h["on_time_frac"]) for h in hist) / len(hist)
        buffered = sum(float(h["buffered"]) for h in hist)
        abandoned = sum(float(h["abandoned"]) for h in hist)
        metrics = sink.by_kind("metrics")
        warnings = sink.by_kind("warning")
        trails.append((frac,
                       [r["obs/buffer/occupancy"] for r in metrics],
                       [r["obs/cohort/trust_q"][1] for r in metrics]))
        print(f"{frac:10.0%} {max(accs):8.3f} {accs[-1]:6.3f} "
              f"{on_time:7.0%} {buffered:8.0f} {abandoned:9.0f} "
              f"{len(warnings):8d}")

    print("\ntelemetry: retry-buffer occupancy and cohort trust p50 per "
          "round\n(the counters ride the scan carry — one host sync per "
          "chunk, numerics\nbit-identical with telemetry off)")
    for frac, occupancy, trust_p50 in trails:
        occ = " ".join(f"{v:3.0f}" for v in occupancy)
        print(f"{frac:4.0%} occupancy [{occ}]  "
              f"trust_p50 {trust_p50[0]:.2f}->{trust_p50[-1]:.2f}")

    print(f"\nevery cohort client is billed once per computed round "
          f"({float(state.cost_client_rounds):.0f} client-rounds at 45% "
          f"stragglers — identical to the fault-free bill): timed-out "
          f"work is billed-but-lost, and chronic stragglers' trust "
          f"decays so the Gumbel-top-d scheduler routes around them "
          f"(graceful degradation instead of a straggler-paced round "
          f"clock)")


if __name__ == "__main__":
    main()
