"""Buffered-async healthcare walkthrough (ROADMAP item 1): a regional
network registers 60 clinics but only 12 report per round, and an
increasing share of them are chronic stragglers (rural links, shared
imaging workstations).  The synchronous engine must either wait for the
slowest clinic or drop its work; the buffered-async engine samples a
cohort by fitness x trust (O(M) Gumbel-top-d over the ClientStore),
races each delivery against a round deadline, parks the late ones in a
staleness-weighted retry buffer, and routes around clinics that keep
timing out — so accuracy degrades GRACEFULLY as the straggler rate
climbs.

Sweeps the straggler rate over {0%, 15%, 30%, 45%} and prints best/final
accuracy, on-time fraction, buffered deliveries and abandoned work for
the async engine, against the fault-free synchronous baseline.

  PYTHONPATH=src python examples/async_healthcare.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import async_engine, fedfits
from repro.core.faults import FaultConfig
from repro.data.pipeline import build_federation

M, C, ROUNDS = 60, 12, 12       # registered clinics, cohort, rounds

from repro.models.model import build

model = build(ARCHS["paper-mlp"])
federation, server_test = build_federation(
    seed=0, kind="tabular", n=3000, n_clients=M, batch_size=32,
    n_classes=10, sep=1.0, dirichlet_alpha=1.0)


@jax.jit
def evaluate(params):
    _, m = model.loss(params, server_test)
    return {"test_acc": m["acc"]}


cfg = FedConfig(n_clients=C, population=M, algorithm="fedavg",
                aggregator="trimmed_mean", local_epochs=2, local_lr=0.2,
                async_deadline=1.0, async_max_retries=2,
                async_backoff=1.5, staleness_decay=0.5)

# fault-free synchronous reference: a C-clinic federation where everyone
# always answers (the best case the async engine is measured against)
sync_fed, sync_test = build_federation(
    seed=0, kind="tabular", n=3000, n_clients=C, batch_size=32,
    n_classes=10, sep=1.0, dirichlet_alpha=1.0)
sync_cfg = FedConfig(n_clients=C, algorithm="fedavg",
                     aggregator="trimmed_mean", local_epochs=2,
                     local_lr=0.2)


@jax.jit
def evaluate_sync(params):
    _, m = model.loss(params, sync_test)
    return {"test_acc": m["acc"]}


_, h_sync = fedfits.run(model, sync_cfg, sync_fed.data_fn, ROUNDS,
                        jax.random.PRNGKey(1), eval_fn=evaluate_sync)
sync_best = max(float(h["test_acc"]) for h in h_sync)
print(f"{M} registered clinics, cohort {C}/round, {ROUNDS} rounds")
print(f"synchronous fault-free baseline: best_acc={sync_best:.3f}\n")
print(f"{'stragglers':>10s} {'best_acc':>8s} {'final':>6s} "
      f"{'on_time':>7s} {'buffered':>8s} {'abandoned':>9s}")

for frac in (0.0, 0.15, 0.30, 0.45):
    fl = FaultConfig(straggler_frac=frac, straggler_delay=3.0,
                     base_delay=0.3) if frac else FaultConfig()
    state, hist = async_engine.run_async(
        model, cfg, federation.data, ROUNDS, jax.random.PRNGKey(1),
        eval_fn=evaluate, batch_size=32, faults=fl)
    accs = [float(h["test_acc"]) for h in hist]
    on_time = sum(float(h["on_time_frac"]) for h in hist) / len(hist)
    buffered = sum(float(h["buffered"]) for h in hist)
    abandoned = sum(float(h["abandoned"]) for h in hist)
    print(f"{frac:10.0%} {max(accs):8.3f} {accs[-1]:6.3f} "
          f"{on_time:7.0%} {buffered:8.0f} {abandoned:9.0f}")

print(f"\nevery cohort client is billed once per computed round "
      f"({float(state.cost_client_rounds):.0f} client-rounds at 45% "
      f"stragglers — identical to the fault-free bill): timed-out work "
      f"is billed-but-lost, and chronic stragglers' trust decays so the "
      f"Gumbel-top-d scheduler routes around them (graceful degradation "
      f"instead of a straggler-paced round clock)")
