"""Serving example: batched requests against a federated-trained decoder —
prefill + autoregressive decode with KV cache, plus the sliding-window
ring-cache (long-context) mode of the long_500k shape at laptop scale.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models.model import build

cfg = ARCHS["tiny-lm"].replace(n_layers=4, d_model=256, n_heads=4,
                               n_kv_heads=2, d_ff=512, vocab_size=2048,
                               head_dim=64)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

B, P, G = 4, 64, 24
requests = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)

for ring, label in [(False, "full KV cache (decode_32k path)"),
                    (True, "ring cache, window=32 (long_500k path)")]:
    c = cfg.replace(sliding_window=32) if ring else cfg
    m = build(c)
    cache = m.init_cache(B, P + G, ring=ring, dtype=jnp.float32)
    prefill = jax.jit(m.prefill)
    decode = jax.jit(m.decode)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": requests}, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    outs = [tok]
    for i in range(G - 1):
        logits, cache = decode(params, {"tokens": tok}, cache,
                               jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(outs, axis=1)
    kv_rows = jax.tree_util.tree_leaves(cache)[0].shape
    print(f"{label}\n  batch={B} prompt={P} generated={G} "
          f"wall={dt:.2f}s ({B * G / dt:.1f} tok/s)"
          f"\n  cache leaf shape: {kv_rows}"
          f"\n  first request continuation: {gen[0, :10].tolist()}\n")
