"""Healthcare scenario (paper SSVI-C): hospitals hold non-IID chest-X-ray-
like data; three of ten are unreliable (label-flipping). Compares FedAvg,
FedRand, FedPow and FedFiTS on accuracy, robustness, cost and fairness.

  PYTHONPATH=src python examples/fl_healthcare_sim.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import attacks, fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build

K, ROUNDS, N_MAL = 10, 15, 3

model = build(ARCHS["paper-cnn"].replace(vocab_size=2))  # pneumonia: 2-class
federation, server_test = build_federation(
    seed=0, kind="images", n=2400, n_clients=K, batch_size=32, n_classes=2,
    dirichlet_alpha=0.3, sep=0.6)   # hard mode: baselines cannot saturate

malicious = jnp.zeros((K,)).at[jnp.arange(N_MAL)].set(1.0)


def data_attack(data, mal, rng):
    return {"y": attacks.label_flip(data["y"], 2, mal)}


@jax.jit
def evaluate(params):
    loss, m = model.loss(params, server_test)
    return {"test_acc": m["acc"]}


print(f"{K} hospitals, {N_MAL} compromised (label flipping)\n")
results = {}
for algo in ["fedavg", "fedrand", "fedpow", "fedfits"]:
    cfg = FedConfig(n_clients=K, algorithm=algo, local_epochs=2,
                    local_lr=0.15, msl=4, pft=2, beta=0.1,
                    fedrand_c=0.7, fedpow_m=6)
    state, hist = fedfits.run(model, cfg, federation.data_fn, ROUNDS,
                              jax.random.PRNGKey(1), eval_fn=evaluate,
                              data_attack=data_attack, malicious=malicious)
    accs = [float(h["test_acc"]) for h in hist]
    mal_sel = float(state.cum_selected[:N_MAL].sum())
    hon_sel = float(state.cum_selected[N_MAL:].sum())
    results[algo] = dict(best=max(accs), final=accs[-1],
                         cost=float(state.cost_client_rounds),
                         mal_sel=mal_sel, hon_sel=hon_sel)
    print(f"{algo:8s} best_acc={max(accs):.3f} final={accs[-1]:.3f} "
          f"cost={results[algo]['cost']:.0f} client-rounds "
          f"(compromised selected {mal_sel:.0f}x vs honest {hon_sel:.0f}x)")

top = max(r["best"] for r in results.values())
leaders = [a for a, r in results.items() if r["best"] >= top - 1e-6]
fit = results["fedfits"]
print(f"\nbest under attack: {'/'.join(leaders)} "
      f"(paper Table V finding: FedFiTS leads under poisoning; on ties, "
      f"its margin is the exclusion of compromised clients below)")
print(f"FedFiTS selected compromised hospitals "
      f"{fit['mal_sel'] / max(fit['mal_sel'] + fit['hon_sel'], 1):.0%} "
      f"of the time — the trust/fitness gate at work")
