"""Unit tests: selection policies (FedFiTS election + baselines)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import selection

KEY = jax.random.PRNGKey(0)
AVAIL = jnp.ones((8,), jnp.float32)


def test_fedfits_selects_above_threshold():
    scores = jnp.array([0.9, 0.8, 0.7, 0.6, 0.1, 0.1, 0.1, 0.1])
    mask = selection.fedfits_select(scores, beta=0.0, avail=AVAIL, rng=KEY)
    mean = float(scores.mean())
    expected = (np.asarray(scores) >= mean).astype(np.float32)
    assert np.array_equal(np.asarray(mask), expected)


def test_beta_opens_the_door():
    """Larger beta admits borderline (yellow) clients — paper Fig. 1b."""
    scores = jnp.array([1.0, 0.95, 0.5, 0.44, 0.1, 0.1, 0.1, 0.1])
    closed = selection.fedfits_select(scores, 0.0, AVAIL, KEY)
    open_ = selection.fedfits_select(scores, 0.5, AVAIL, KEY)
    assert open_.sum() >= closed.sum()


def test_unavailable_clients_never_selected():
    scores = jnp.ones((8,))
    avail = AVAIL.at[3].set(0.0)
    mask = selection.fedfits_select(scores, 0.5, avail, KEY)
    assert float(mask[3]) == 0.0


def test_empty_team_fallback():
    # all scores equal and below an impossible threshold cannot happen via
    # Eq.3, so force it with beta<0 (threshold above mean)
    scores = jnp.array([1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 2.0])
    mask = selection.fedfits_select(scores, -0.9, AVAIL, KEY, min_team=1)
    assert float(mask.sum()) >= 1.0


def test_participation_floor_includes_everyone():
    scores = jnp.array([1.0] * 7 + [0.0])
    sel = np.zeros(8)
    for i in range(200):
        m = selection.fedfits_select(scores, 0.0, AVAIL,
                                     jax.random.fold_in(KEY, i),
                                     floor_prob=0.3)
        sel += np.asarray(m)
    assert sel[7] > 20  # starving client still participates ~30% of rounds


def test_fedrand_team_size():
    for c in [0.25, 0.5, 1.0]:
        m = selection.fedrand_select(AVAIL, c, KEY)
        assert float(m.sum()) == np.ceil(c * 8)


def test_fedpow_picks_highest_loss():
    losses = jnp.array([0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8])
    m = selection.fedpow_select(losses, AVAIL, d=8, m=3, rng=KEY)
    assert np.array_equal(np.where(np.asarray(m) > 0)[0], [5, 6, 7])


def test_fedpow_candidates_proportional_to_data_size():
    """Power-of-choice samples its candidate set ∝ n_k (Gumbel-top-d).
    With d = m = 1 and equal losses the selected client IS the candidate,
    whose marginal is exactly n_k / sum n — check the empirical
    frequencies (4σ tolerance at 3000 trials)."""
    k = 4
    n = jnp.array([8.0, 4.0, 2.0, 1.0])
    avail = jnp.ones((k,))
    losses = jnp.zeros((k,))
    sel = jax.jit(lambda r: selection.fedpow_select(losses, avail, 1, 1, r,
                                                    n=n))
    counts = np.zeros(k)
    trials = 3000
    for i in range(trials):
        counts += np.asarray(sel(jax.random.fold_in(KEY, i)))
    freq = counts / trials
    np.testing.assert_allclose(freq, np.asarray(n / n.sum()), atol=0.04)
    # proportional, hence monotone in n
    assert freq[0] > freq[1] > freq[2] > freq[3]


def test_fedpow_unavailable_never_candidates_despite_big_n():
    n = jnp.array([1000.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
    avail = AVAIL.at[0].set(0.0)
    losses = jnp.linspace(1.0, 0.1, 8)
    for i in range(50):
        m = selection.fedpow_select(losses, avail, 4, 2,
                                    jax.random.fold_in(KEY, i), n=n)
        assert float(m[0]) == 0.0


def test_participation_ratio():
    assert float(selection.participation_ratio(jnp.array([0, 1, 2, 0.0]))) \
        == 0.5
