"""Beyond-paper / Table-II-roadmap extensions: FedProx regularisation,
client availability (stragglers), async catch-up, ZeRO-1 train step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, TrainConfig
from repro.configs.registry import ARCHS
from repro.core import fedfits, pod
from repro.data.pipeline import build_federation
from repro.models import transformer
from repro.models.model import build
from repro.optim import optimizers

K = 6


def _setup():
    model = build(ARCHS["paper-mlp"])
    fed, test = build_federation(0, kind="tabular", n=900, n_clients=K,
                                 batch_size=16, n_classes=22)

    @jax.jit
    def eval_fn(params):
        l, m = model.loss(params, test)
        return {"test_acc": m["acc"]}

    return model, fed, eval_fn


def test_fedprox_still_converges():
    model, fed, eval_fn = _setup()
    cfg = FedConfig(n_clients=K, algorithm="fedfits", local_epochs=3,
                    local_lr=0.05, prox_mu=0.1)
    _, hist = fedfits.run(model, cfg, fed.data_fn, 10,
                          jax.random.PRNGKey(0), eval_fn=eval_fn)
    assert hist[-1]["test_acc"] > 0.6


def test_availability_masks_respected():
    model, fed, eval_fn = _setup()
    cfg = FedConfig(n_clients=K, algorithm="fedfits", local_epochs=1,
                    local_lr=0.05, avail_prob=0.6)
    _, hist = fedfits.run(model, cfg, fed.data_fn, 10,
                          jax.random.PRNGKey(1), eval_fn=eval_fn)
    sizes = [float(h["team_size"]) for h in hist[1:]]
    assert min(sizes) >= 1.0
    assert np.isfinite(hist[-1]["test_acc"])
    # stragglers actually shrink some teams below the full-availability run
    assert min(sizes) < K


def test_async_catchup_runs():
    model, fed, eval_fn = _setup()
    cfg = FedConfig(n_clients=K, algorithm="fedfits", local_epochs=1,
                    local_lr=0.05, avail_prob=0.5, stale_weight=0.3)
    _, hist = fedfits.run(model, cfg, fed.data_fn, 8,
                          jax.random.PRNGKey(2), eval_fn=eval_fn)
    assert np.isfinite(hist[-1]["test_acc"])


def test_zero1_matches_baseline_loss():
    """ZeRO-1 step (bf16 compute copy) ~= baseline on a 1x1 mesh."""
    from jax.sharding import NamedSharding
    from repro.sharding import specs as sh

    cfg = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab_size=128,
                                   head_dim=16)
    fed = FedConfig(n_clients=2)
    tc = TrainConfig(global_batch=4, seq_len=16, total_steps=4,
                     warmup_steps=1)
    key = jax.random.PRNGKey(0)
    params = transformer.init_transformer(key, cfg)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, 2, fed, key)
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, 128),
             "targets": jax.random.randint(key, (4, 16), 0, 128)}

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    compute_sh = sh.named(mesh, sh.param_specs_tp(params, mesh=mesh))
    master_sh = sh.named(mesh, sh.param_specs(params, mesh=mesh))

    base_step = jax.jit(pod.make_train_step(cfg, fed, tc))
    z1_step = jax.jit(pod.make_train_step(
        cfg, fed, tc, zero1_shardings=(compute_sh, master_sh)))
    with mesh:
        _, m_base = base_step(state, batch)
        _, m_z1 = z1_step(state, batch)
    assert abs(float(m_base["loss"]) - float(m_z1["loss"])) < 0.05
    assert np.isfinite(float(m_z1["grad_norm"]))
