"""PodEngine integration: the SPMD FL round (weighted + robust paths),
loss actually decreases, client masking semantics hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, TrainConfig
from repro.configs.registry import ARCHS
from repro.core import pod
from repro.data import synthetic
from repro.models import transformer
from repro.optim import optimizers

KEY = jax.random.PRNGKey(0)
CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab_size=128,
                               head_dim=16)
C, B, S = 4, 8, 32


def _state(fed, tc):
    params = transformer.init_transformer(KEY, CFG)
    opt_init, _ = optimizers.make_optimizer(tc)
    return pod.init_pod_state(params, opt_init, C, fed, KEY)


def _batch(seed=0):
    toks = synthetic.make_lm_tokens(jax.random.PRNGKey(seed), B, S + 1,
                                    CFG.vocab_size, n_latent=2)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_loss_decreases():
    fed = FedConfig(n_clients=C)
    tc = TrainConfig(global_batch=B, seq_len=S, lr=1e-2, warmup_steps=2,
                     total_steps=30)
    state = _state(fed, tc)
    step = jax.jit(pod.make_train_step(CFG, fed, tc))
    losses = []
    for i in range(20):
        state, m = step(state, _batch(i % 4))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]


def test_robust_path_equivalent_dims_and_finite():
    fed = FedConfig(n_clients=C, aggregator="median")
    tc = TrainConfig(global_batch=B, seq_len=S, total_steps=4,
                     warmup_steps=1)
    state = _state(fed, tc)
    step = jax.jit(pod.make_train_step(CFG, fed, tc, robust="per_client"))
    state2, m = step(state, _batch())
    assert np.isfinite(float(m["loss"]))
    for a, b in zip(jax.tree_util.tree_leaves(state.params),
                    jax.tree_util.tree_leaves(state2.params)):
        assert a.shape == b.shape


def test_fed_state_round_trips_through_step():
    fed = FedConfig(n_clients=C, msl=2, pft=1)
    tc = TrainConfig(global_batch=B, seq_len=S, total_steps=8,
                     warmup_steps=1)
    state = _state(fed, tc)
    step = jax.jit(pod.make_train_step(CFG, fed, tc))
    rounds = []
    for i in range(4):
        state, m = step(state, _batch(i))
        rounds.append(int(state.fed.round))
    assert rounds == [2, 3, 4, 5]
    assert state.fed.team.shape == (C,)
    assert float(state.fed.team.sum()) >= 1.0
    assert 0.0 <= float(state.fed.alpha) <= 1.0


def test_zero_trust_client_does_not_move_params():
    """A client with trust=0 (and out of team) contributes nothing."""
    fed = FedConfig(n_clients=C, dynamic_alpha=False)
    tc = TrainConfig(global_batch=B, seq_len=S, lr=1e-2, warmup_steps=1,
                     total_steps=4, grad_clip=0.0)
    state = _state(fed, tc)
    # kill client 0
    fedst = state.fed._replace(
        team=jnp.array([0.0, 1.0, 1.0, 1.0]),
        trust=jnp.array([0.0, 1.0, 1.0, 1.0]),
        h=jnp.array(False))
    state = state._replace(fed=fedst)
    step = jax.jit(pod.make_train_step(CFG, fed, tc))

    batch = _batch()
    state_a, _ = step(state, batch)
    # corrupt client 0's rows wildly; grads must be identical
    bc = B // C
    tok2 = batch["tokens"].at[:bc].set(
        (batch["tokens"][:bc] + 17) % CFG.vocab_size)
    batch2 = {"tokens": tok2, "targets": batch["targets"]}
    state_b, _ = step(state, batch2)
    for a, b in zip(jax.tree_util.tree_leaves(state_a.params),
                    jax.tree_util.tree_leaves(state_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
