"""Continuous-batching serving engine (repro/serve + paged kernels).

Covers the paged flash-decode kernel (parity vs the dense oracle across
page sizes, ragged last pages, GQA, inactive slots, the int8 fused
dequant path with documented error bounds), the slot scheduler (argsort
slot/page picks, the host ledger mirror, request validation), the
end-to-end engine (exact token accounting, page conservation under
churn, continuous == fixed == dense-full-cache parity under argmax,
max_new=1 completing at admission), and the serving telemetry artifacts
(measured round spans + Perfetto counter tracks, schema checks)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.paged_decode import paged_flash_decode
from repro.kernels.paged_decode_ref import (dequant_pool, gather_pages,
                                            paged_decode_ref)
from repro.launch.serve import draw_requests, make_decode_step
from repro.models.model import build
from repro.serve import (HostLedger, Request, ServeConfig, ServeEngine,
                         kv_bytes_read)
from repro.serve import scheduler as sched

# measured fp32 kernel-vs-oracle gap is ~2e-7; int8 kernel vs the int8
# oracle is exact modulo fp32 op order (~3e-7), while int8 vs fp32 is
# quantization error (~1.3e-2 for unit-normal K/V at qblk = head_dim)
FP32_ATOL = 1e-5
INT8_KERNEL_ATOL = 2e-5
INT8_QUANT_ATOL = 5e-2


def _rand_paged(seed, s, maxp, page, hq, hkv, dh, n_extra=3):
    """Random pool + table + ragged lengths (incl. one inactive slot)."""
    key = jax.random.PRNGKey(seed)
    n = s * maxp + n_extra
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (s, hq, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (n, page, hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (n, page, hkv, dh), jnp.float32)
    table = jax.random.permutation(ks[3], n)[:s * maxp].reshape(s, maxp)
    # ragged: full pages, partial last page, single row, inactive (0)
    lengths = jax.random.randint(ks[4], (s,), 1, maxp * page + 1)
    lengths = lengths.at[0].set(maxp * page)       # every page full
    lengths = lengths.at[1].set(page + 1)          # ragged last page
    if s > 2:
        lengths = lengths.at[2].set(0)             # inactive slot
    return q, kp, vp, table.astype(jnp.int32), lengths.astype(jnp.int32)


class TestPagedKernel:
    @pytest.mark.parametrize("page,maxp", [(4, 6), (8, 3), (16, 2)])
    def test_parity_vs_ref_across_page_sizes(self, page, maxp):
        q, kp, vp, table, lengths = _rand_paged(page, 4, maxp, page,
                                                hq=4, hkv=2, dh=64)
        out = paged_flash_decode(q, kp, vp, table, lengths,
                                 interpret=True)
        ref = paged_decode_ref(q, kp, vp, table, lengths)
        np.testing.assert_allclose(out, ref, atol=FP32_ATOL)

    def test_parity_vs_plain_sdpa(self):
        page, maxp, s = 8, 4, 3
        q, kp, vp, table, lengths = _rand_paged(7, s, maxp, page,
                                                hq=4, hkv=2, dh=64)
        out = paged_flash_decode(q, kp, vp, table, lengths,
                                 interpret=True)
        k = gather_pages(kp, table)
        v = gather_pages(vp, table)
        g = 4 // 2
        for si in range(s):
            L = int(lengths[si])
            if L == 0:
                continue
            for h in range(4):
                qs = np.asarray(q[si, h]) / np.sqrt(64)
                logits = qs @ np.asarray(k[si, :L, h // g]).T
                p = np.exp(logits - logits.max())
                p /= p.sum()
                expect = p @ np.asarray(v[si, :L, h // g])
                np.testing.assert_allclose(out[si, h], expect,
                                           atol=FP32_ATOL)

    def test_inactive_slot_outputs_zero(self):
        q, kp, vp, table, lengths = _rand_paged(1, 4, 3, 8,
                                                hq=4, hkv=2, dh=64)
        out = paged_flash_decode(q, kp, vp, table, lengths,
                                 interpret=True)
        assert int(lengths[2]) == 0
        np.testing.assert_array_equal(np.asarray(out[2]), 0.0)

    def test_int8_kernel_matches_int8_oracle(self):
        from repro.models.attention import _paged_quant
        q, kp, vp, table, lengths = _rand_paged(11, 4, 3, 8,
                                                hq=4, hkv=2, dh=64)
        kq, ksc = _paged_quant(kp)
        vq, vsc = _paged_quant(vp)
        out = paged_flash_decode(q, kq, vq, table, lengths,
                                 k_scale=ksc, v_scale=vsc,
                                 interpret=True)
        ref = paged_decode_ref(q, kq, vq, table, lengths,
                               k_scale=ksc, v_scale=vsc)
        np.testing.assert_allclose(out, ref, atol=INT8_KERNEL_ATOL)
        # dequant helper round-trips the codes the ref consumed
        np.testing.assert_allclose(dequant_pool(kq, ksc), kp,
                                   atol=INT8_QUANT_ATOL)

    def test_int8_vs_fp32_quantization_bound(self):
        from repro.models.attention import _paged_quant
        q, kp, vp, table, lengths = _rand_paged(13, 4, 3, 8,
                                                hq=4, hkv=2, dh=64)
        kq, ksc = _paged_quant(kp)
        vq, vsc = _paged_quant(vp)
        out8 = paged_flash_decode(q, kq, vq, table, lengths,
                                  k_scale=ksc, v_scale=vsc,
                                  interpret=True)
        out32 = paged_decode_ref(q, kp, vp, table, lengths)
        err = float(jnp.max(jnp.abs(out8 - out32)))
        assert err < INT8_QUANT_ATOL, err


class TestScheduler:
    def test_pick_free_slot_first_inactive(self):
        active = jnp.asarray([1.0, 0.0, 1.0, 0.0])
        slot, ok = sched.pick_free_slot(active)
        assert int(slot) == 1 and bool(ok)
        slot, ok = sched.pick_free_slot(jnp.ones((3,)))
        assert not bool(ok)

    def test_take_pages_and_infeasible(self):
        free = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        pages, ok, free2 = sched.take_pages(free, jnp.int32(2), 3)
        assert bool(ok)
        assert sorted(np.asarray(pages)[:2].tolist()) == [0, 2]
        assert float(free2.sum()) == 1.0
        # infeasible: nothing taken
        _, ok, free3 = sched.take_pages(free2, jnp.int32(2), 3)
        assert not bool(ok)
        np.testing.assert_array_equal(np.asarray(free3),
                                      np.asarray(free2))

    def test_validate_request(self):
        scfg = ServeConfig(max_slots=2, page_size=4, max_len=16,
                           prompt_pad=8)
        sched.validate_request(Request(0, (1, 2, 3), 4), scfg)
        with pytest.raises(ValueError):
            sched.validate_request(Request(1, (), 4), scfg)
        with pytest.raises(ValueError):
            sched.validate_request(Request(2, tuple(range(9)), 4), scfg)
        with pytest.raises(ValueError):
            sched.validate_request(Request(3, (1,), 0), scfg)

    def test_host_ledger_mirror(self):
        scfg = ServeConfig(max_slots=2, page_size=4, max_len=16,
                           prompt_pad=4)
        led = HostLedger(scfg)
        assert led.can_admit(4) and led.next_slot() == 0
        led.admit_at(0, 4)
        assert led.next_slot() == 1 and led.free_pages == 4
        led.admit_at(1, 4)
        assert not led.can_admit(1)
        led.evict(0)
        assert led.next_slot() == 0 and led.free_pages == 4

    def test_kv_bytes_read_int8_reduction(self):
        cfg = get_config("tiny-lm").reduced()
        f32 = kv_bytes_read(cfg, ServeConfig(page_size=16), 4.0)
        i8 = kv_bytes_read(cfg, ServeConfig(page_size=16, kv_int8=True),
                           4.0)
        assert f32 / i8 > 3.0


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny-lm").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _scfg(**kw):
    base = dict(max_slots=4, page_size=8, max_len=48, prompt_pad=8,
                attn="ref")
    base.update(kw)
    return ServeConfig(**base)


class TestEngine:
    def test_churn_exact_token_counts_and_page_conservation(self, tiny):
        cfg, _, params = tiny
        scfg = _scfg()
        engine = ServeEngine(cfg, scfg, params, seed=2)
        reqs = draw_requests(10, 6, 2, 24, cfg.vocab_size, seed=5)
        results, stats = engine.run(reqs, continuous=True)
        for r in reqs:
            assert len(results[r.req_id]) == r.max_new, r
        assert stats["free_pages_end"] == scfg.total_pages
        assert stats["tokens"] == sum(r.max_new for r in reqs)

    def test_continuous_matches_fixed_tokens(self, tiny):
        cfg, _, params = tiny
        scfg = _scfg()
        reqs = draw_requests(6, 6, 2, 16, cfg.vocab_size, seed=9)
        engine = ServeEngine(cfg, scfg, params, seed=0)
        cont, s_cont = engine.run(reqs, continuous=True)
        fixed, s_fixed = engine.run(reqs, continuous=False)
        assert cont == fixed          # argmax: scheduling can't change tokens
        assert s_cont["steps"] <= s_fixed["steps"]

    def test_admit_order_independence_per_request(self, tiny):
        # a request's tokens depend on its prompt, not on its
        # companions' slot churn (argmax decoding)
        cfg, _, params = tiny
        scfg = _scfg()
        engine = ServeEngine(cfg, scfg, params, seed=0)
        reqs = draw_requests(6, 6, 2, 12, cfg.vocab_size, seed=4)
        a, _ = engine.run(reqs, continuous=True)
        b, _ = engine.run(list(reversed(reqs)), continuous=True)
        assert a == b

    def test_paged_matches_dense_full_cache(self, tiny):
        cfg, model, params = tiny
        plen, gen = 5, 8
        prompt = tuple(np.random.RandomState(3)
                       .randint(0, cfg.vocab_size, plen).tolist())
        engine = ServeEngine(cfg, _scfg(), params, seed=0)
        results, _ = engine.run([Request(0, prompt, gen)],
                                continuous=True)
        # dense oracle: full-cache prefill + greedy decode
        cache = model.init_cache(1, plen + gen, dtype=jnp.float32)
        toks = jnp.asarray(prompt, jnp.int32)[None]
        logits, cache = jax.jit(model.prefill)(
            params, {"tokens": toks}, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        step = jax.jit(make_decode_step(model, temperature=0.0))
        dense = [int(tok[0, 0])]
        key = jax.random.PRNGKey(0)
        for i in range(gen - 1):
            tok, cache, key = step(params, tok, cache,
                                   jnp.int32(plen + i), key)
            dense.append(int(tok[0, 0]))
        assert results[0] == dense

    def test_max_new_1_completes_at_admission(self, tiny):
        cfg, _, params = tiny
        scfg = _scfg()
        engine = ServeEngine(cfg, scfg, params, seed=0)
        results, stats = engine.run([Request(0, (1, 2, 3), 1)],
                                    continuous=True)
        assert len(results[0]) == 1
        assert stats["steps"] == 0
        assert stats["free_pages_end"] == scfg.total_pages

    def test_int8_engine_end_to_end(self, tiny):
        cfg, _, params = tiny
        engine = ServeEngine(cfg, _scfg(kv_int8=True), params, seed=0)
        reqs = draw_requests(4, 6, 2, 10, cfg.vocab_size, seed=1)
        results, stats = engine.run(reqs, continuous=True)
        for r in reqs:
            assert len(results[r.req_id]) == r.max_new
        assert stats["free_pages_end"] == engine.scfg.total_pages

    def test_pallas_engine_matches_ref_engine(self, tiny):
        cfg, _, params = tiny
        reqs = draw_requests(3, 6, 2, 8, cfg.vocab_size, seed=2)
        ref, _ = ServeEngine(cfg, _scfg(attn="ref"), params,
                             seed=0).run(reqs)
        pal, _ = ServeEngine(cfg, _scfg(attn="pallas"), params,
                             seed=0).run(reqs)
        assert ref == pal


class TestServeTelemetry:
    def test_trace_and_jsonl_artifacts(self, tiny, tmp_path):
        from repro import obs
        from repro.obs.check import check_jsonl, check_trace
        cfg, _, params = tiny
        trace_p = str(tmp_path / "trace.json")
        jsonl_p = str(tmp_path / "obs.jsonl")
        tel = obs.Telemetry(sinks=[obs.JsonlSink(jsonl_p)],
                            trace_path=trace_p, run_name="serve-test")
        engine = ServeEngine(cfg, _scfg(), params, seed=0)
        reqs = draw_requests(4, 6, 2, 10, cfg.vocab_size, seed=0)
        engine.run(reqs, telemetry=tel, continuous=True)
        tel.finish()
        assert check_trace(trace_p) == []
        assert check_jsonl(jsonl_p, require_obs=True,
                           engine="serve") == []
        with open(trace_p) as f:
            evs = json.load(f)["traceEvents"]
        rounds = [e for e in evs if e["name"] == "round"
                  and e["ph"] == "X"]
        counters = [e for e in evs if e.get("ph") == "C"]
        assert rounds, "no measured round spans"
        assert all("attributed" not in e.get("args", {})
                   for e in rounds)
        tracks = {e["name"] for e in counters}
        assert "serve/slot_occupancy" in tracks
        assert "serve/pages_in_use" in tracks

    def test_measured_wire_bytes_rows(self):
        from repro.launch.roofline import measured_wire_bytes
        rows = [{"obs/wire/bytes_up": 100.0, "obs/wire/bytes_down": 40.0},
                {"obs/wire/bytes_up": 50.0, "obs/wire/bytes_down": 20.0}]
        w = measured_wire_bytes(rows)
        assert w["rounds"] == 2
        assert w["bytes_up"] == 150.0
        assert w["bytes_up_per_round"] == 75.0
        assert w["bytes_down"] == 60.0
