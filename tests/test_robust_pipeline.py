"""Fused two-pass robust-aggregation pipeline (kernels/robust_pipeline.py)
vs the multi-pass XLA oracles — leaf-streaming (segment-table) engine,
the PR-1 flatten baseline, dtype round-trips, and the jaxpr no-copy
guarantee — plus the scan round-driver equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.traversal import all_eqns
from repro.configs.base import FedConfig
from repro.core import aggregation
from repro.kernels.robust_pipeline import (auto_blk, fused_aggregate_tree,
                                           fused_aggregate_tree_flat,
                                           fused_two_stage_tree,
                                           fused_two_stage_tree_flat,
                                           make_segments,
                                           pairwise_sq_dists_blocked)

KEY = jax.random.PRNGKey(0)
AGGS = ["fedavg", "median", "trimmed_mean", "krum"]


def _tree(c, key=KEY):
    return {"a": jax.random.normal(key, (c, 13, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (c, 301))}


def _assert_tree_close(out, ref, atol=1e-5):
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(ref[k], np.float32), atol=atol)


@pytest.mark.parametrize("agg", AGGS)
@pytest.mark.parametrize("c", [8, 9])            # even + odd C
def test_fused_matches_ref_all_modes(agg, c):
    tree = _tree(c)
    mask = jnp.ones((c,)).at[2].set(0.0)         # partial mask
    w = jax.random.uniform(jax.random.fold_in(KEY, 2), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg)
    out = fused_aggregate_tree(tree, w, mask, cfg, blk=128)
    ref = aggregation.aggregate_ref(tree, w, mask, cfg)
    _assert_tree_close(out, ref)


@pytest.mark.parametrize("agg", ["median", "trimmed_mean"])
def test_fused_pad_path(agg):
    """N = 13*7 + 301 = 392 with blk=256 -> pad 120 zero columns; the pad
    must not perturb the cosine gate or the aggregated coordinates."""
    c = 7
    tree = _tree(c)
    mask = jnp.ones((c,)).at[0].set(0.0).at[4].set(0.0)
    cfg = FedConfig(n_clients=c, aggregator=agg)
    out = fused_aggregate_tree(tree, jnp.ones((c,)), mask, cfg, blk=256)
    ref = aggregation.aggregate_ref(tree, jnp.ones((c,)), mask, cfg)
    _assert_tree_close(out, ref)


def test_fused_gate_excises_sign_flipped_clients():
    c = 8
    honest = jax.random.normal(KEY, (c, 30)) * 0.01 + 1.0
    upd = {"w": honest.at[0].set(-50.0).at[1].set(-50.0)}
    cfg = FedConfig(n_clients=c, aggregator="median")
    out = fused_aggregate_tree(upd, jnp.ones((c,)), jnp.ones((c,)), cfg)
    assert np.all(np.asarray(out["w"]) > 0.5)


@pytest.mark.parametrize("agg", AGGS)
def test_two_stage_cohort_batched_matches_ref(agg):
    g, k = 3, 8
    upd = {"w": jax.random.normal(KEY, (g, k, 57)),
           "b": jax.random.normal(jax.random.fold_in(KEY, 3), (g, k, 5, 3))}
    sw = jax.random.uniform(jax.random.fold_in(KEY, 4), (g, k)) + 0.1
    sm = jnp.ones((g, k)).at[0, 3].set(0.0).at[2, 1].set(0.0)
    cfg = FedConfig(aggregator=agg)
    out = fused_two_stage_tree(upd, sw, sm, cfg, blk=128)
    ref = aggregation.two_stage_ref(upd, sw, sm, cfg)
    _assert_tree_close(out, ref)


def test_two_stage_leafwise_matches_flat():
    """Cohort-batched leaf-streaming vs the PR-1 flatten path (kept as
    oracle): same G-grid semantics, no concatenate."""
    g, k = 3, 8
    upd = {"w": jax.random.normal(KEY, (g, k, 57)),
           "b": jax.random.normal(jax.random.fold_in(KEY, 3), (g, k, 5, 3))}
    sw = jax.random.uniform(jax.random.fold_in(KEY, 4), (g, k)) + 0.1
    sm = jnp.ones((g, k)).at[1, 2].set(0.0)
    cfg = FedConfig(aggregator="trimmed_mean")
    out = fused_two_stage_tree(upd, sw, sm, cfg, blk=128)
    ref = fused_two_stage_tree_flat(upd, sw, sm, cfg, blk=128)
    _assert_tree_close(out, ref)


def test_two_stage_router_uses_fused_path():
    g, k = 2, 6
    upd = jax.random.normal(KEY, (g, k, 33))
    sw = jnp.ones((g, k))
    sm = jnp.ones((g, k))
    import dataclasses
    cfg = FedConfig(aggregator="trimmed_mean")
    out = aggregation.two_stage(upd, sw, sm, cfg)
    ref = aggregation.two_stage(upd, sw, sm,
                                dataclasses.replace(cfg, fused_agg=False))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def _mixed_tree(c, key=KEY):
    """Multi-leaf, mixed-dtype, odd-size tree: a ragged f32 matrix, a
    bf16 leaf, a tiny bias-like leaf, and an f16 leaf."""
    return {"a": jax.random.normal(key, (c, 13, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1),
                                   (c, 301)).astype(jnp.bfloat16),
            "c": jax.random.normal(jax.random.fold_in(key, 2), (c, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 3),
                                   (c, 192)).astype(jnp.float16)}


@pytest.mark.parametrize("agg", AGGS)
def test_leafwise_matches_flatten_on_mixed_tree(agg):
    """Leaf-streaming (segment-table) engine vs the PR-1 flatten path on a
    multi-leaf mixed-dtype/odd-size tree."""
    c = 9
    tree = _mixed_tree(c)
    mask = jnp.ones((c,)).at[3].set(0.0)
    w = jax.random.uniform(jax.random.fold_in(KEY, 5), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg)
    leaf = fused_aggregate_tree(tree, w, mask, cfg, blk=128)
    flat = fused_aggregate_tree_flat(tree, w, mask, cfg, blk=128)
    for k in tree:
        assert leaf[k].dtype == tree[k].dtype
        # half-precision leaves: within one ulp of each other's rounding
        atol = 1e-5 if leaf[k].dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(leaf[k], np.float32),
                                   np.asarray(flat[k], np.float32),
                                   atol=atol)


@pytest.mark.parametrize("agg", ["median", "trimmed_mean", "fedavg"])
def test_halfprec_leaves_match_fp32_oracle(agg):
    """bf16/f16 leaves accumulate fp32 throughout with exactly one cast at
    the pass-2 output write: the result must match the fp32 oracle (the
    same tree in fp32) to half-precision resolution — a per-slice cast
    round-trip would drift further."""
    c = 8
    tree = _mixed_tree(c)
    tree32 = jax.tree_util.tree_map(
        lambda l: l.astype(jnp.float32), tree)
    mask = jnp.ones((c,)).at[1].set(0.0)
    w = jnp.ones((c,))
    cfg = FedConfig(n_clients=c, aggregator=agg)
    out = fused_aggregate_tree(tree, w, mask, cfg, blk=128)
    oracle = aggregation.aggregate_ref(tree32, w, mask, cfg)
    for k in tree:
        tol = 1e-5 if tree[k].dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(oracle[k]), atol=tol)


def test_jaxpr_has_no_leaf_sized_concatenate():
    """Acceptance guard for the leaf-streaming rework: the jaxpr of
    ``fused_aggregate_tree`` on a multi-leaf tree must not materialise a
    flattened (C, N) matrix — no concatenate at (or above) leaf size."""
    c = 8
    tree = _mixed_tree(c)
    mask = jnp.ones((c,))
    w = jnp.ones((c,))
    cfg = FedConfig(n_clients=c, aggregator="trimmed_mean")
    jaxpr = jax.make_jaxpr(
        lambda u, ww, m: fused_aggregate_tree(u, ww, m, cfg, blk=128)
    )(tree, w, mask)
    min_leaf = min(int(l.size) for l in tree.values())
    big_concats = [
        eqn for _, eqn in all_eqns(jaxpr)
        if eqn.primitive.name == "concatenate"
        and int(np.prod(eqn.outvars[0].aval.shape)) >= min_leaf]
    assert not big_concats, big_concats


def test_segment_table_and_auto_blk():
    segs, total = make_segments([300, 128, 5], 128)
    # (start, nblocks, n, per-leaf blk): narrow leaves get 128-aligned
    # blocks of their own width, and single-block leaves all share step 0
    # (constant block index -> no extra DMA, no extra grid steps)
    assert [tuple(s) for s in segs] == [
        (0, 3, 300, 128), (0, 1, 128, 128), (0, 1, 5, 128)]
    assert total == 3
    segs, total = make_segments([16384, 379, 5], 16384)
    assert [s.blk for s in segs] == [16384, 384, 128]
    assert total == 1                      # whole tree in one grid step
    segs, total = make_segments([300, 300], 128)
    assert [s.start for s in segs] == [0, 3] and total == 6
    # CPU: never wider than the longest leaf, 128-aligned
    assert auto_blk(8, [300, 128, 5], backend="cpu") == 384
    # cache cap: the (C, C, blk) rank working set stays in the LLC
    assert auto_blk(8, [1 << 20], backend="cpu") == 1 << 15
    assert auto_blk(16, [1 << 20], backend="cpu") == 1 << 14
    # TPU: VMEM-sized, 128-aligned, clamped
    blk = auto_blk(16, [1 << 20], backend="tpu")
    assert 512 <= blk <= 8192 and blk % 128 == 0


def test_pairwise_distance_kernel_matches_ref():
    g, c, n = 2, 9, 300                           # odd C, padded N
    x = jax.random.normal(KEY, (g, c, n))
    mask = jnp.ones((g, c)).at[1, 2].set(0.0)
    pad = (-n) % 128
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
    d = pairwise_sq_dists_blocked(xp, mask, blk=128, interpret=True)
    for gi in range(g):
        ref = aggregation.pairwise_sq_dists(x[gi], mask[gi])
        np.testing.assert_allclose(np.asarray(d[gi]), np.asarray(ref),
                                   atol=1e-2)  # _BIG-masked entries dominate
        real = np.asarray(mask[gi])[:, None] * np.asarray(mask[gi])[None, :]
        np.testing.assert_allclose(np.asarray(d[gi])[real > 0],
                                   np.asarray(ref)[real > 0],
                                   rtol=1e-5, atol=1e-3)


def test_scan_driver_matches_python_loop_bitwise():
    """fedfits.run driver="scan" must reproduce the per-round jit loop
    history (and final state) bit-for-bit on a fixed seed — including a
    ragged tail chunk and availability sampling inside the scan."""
    from repro.configs.registry import ARCHS
    from repro.core import fedfits
    from repro.data.pipeline import build_federation
    from repro.models.model import build

    k = 6
    model = build(ARCHS["paper-mlp"])
    fed, test = build_federation(0, kind="tabular", n=600, n_clients=k,
                                 batch_size=16, n_classes=22)

    @jax.jit
    def eval_fn(params):
        l, m = model.loss(params, test)
        return {"test_loss": l, "test_acc": m["acc"]}

    cfg = FedConfig(n_clients=k, algorithm="fedfits", local_epochs=1,
                    local_lr=0.05, avail_prob=0.7,
                    aggregator="trimmed_mean")
    s_py, h_py = fedfits.run(model, cfg, fed.data_fn, 5,
                             jax.random.PRNGKey(7), eval_fn=eval_fn,
                             driver="python")
    s_sc, h_sc = fedfits.run(model, cfg, fed.data_fn, 5,
                             jax.random.PRNGKey(7), eval_fn=eval_fn,
                             driver="scan", chunk_rounds=3)
    assert len(h_py) == len(h_sc) == 5
    for r_py, r_sc in zip(h_py, h_sc):
        assert set(r_py) == set(r_sc)
        for key in r_py:
            np.testing.assert_array_equal(np.asarray(r_py[key]),
                                          np.asarray(r_sc[key]),
                                          err_msg=f"round {r_py['round']} "
                                                  f"key {key}")
    for a, b in zip(jax.tree_util.tree_leaves(s_py),
                    jax.tree_util.tree_leaves(s_sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
