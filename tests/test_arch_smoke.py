"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward + one train step on CPU — output shapes
correct, no NaNs — and decode agrees with the full-sequence forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, TrainConfig
from repro.configs.registry import ARCHS, ASSIGNED
from repro.core import pod
from repro.models.model import build
from repro.optim import optimizers

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(cfg):
    inp = {}
    if cfg.embed_inputs:
        inp["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    else:
        inp["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    if cfg.arch_type == "vlm":
        inp["image_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 7), (B, cfg.n_image_tokens, cfg.d_model))
    return inp


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_loss(arch):
    cfg = ARCHS[arch].reduced()
    model = build(cfg)
    params = model.init(KEY)
    inp = _inputs(cfg)
    logits = model.forward(params, dict(inp))
    assert logits.shape[:2] == (B, S)
    assert logits.shape[-1] >= cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    batch = dict(inp)
    batch["targets"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["acc"]) <= 1.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_no_nans(arch):
    cfg = ARCHS[arch].reduced()
    fed = FedConfig(n_clients=2)
    tc = TrainConfig(global_batch=B, seq_len=S, total_steps=4,
                     warmup_steps=1)
    from repro.models import transformer
    params = transformer.init_transformer(KEY, cfg)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, 2, fed, KEY)
    step = jax.jit(pod.make_train_step(cfg, fed, tc))
    batch = _inputs(cfg)
    batch["targets"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = ARCHS[arch].reduced()
    model = build(cfg)
    params = model.init(KEY)
    inp = _inputs(cfg)
    full = model.forward(params, dict(inp))
    cache = model.init_cache(B, S + 4, dtype=jnp.float32)
    pre = {k: (v[:, :S - 1] if k != "image_embeds" else v)
           for k, v in inp.items()}
    last = {k: v[:, S - 1:S] for k, v in inp.items() if k != "image_embeds"}
    _, cache = model.prefill(params, pre, cache)
    logits_d, _ = model.decode(params, last, cache, jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(logits_d[:, 0]), atol=2e-4)


def test_ring_cache_decode_sliding_window():
    """long_500k path: ring cache decode == full attention w/ window."""
    cfg = ARCHS["qwen2.5-14b"].reduced().replace(sliding_window=8)
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S + 4, ring=True, dtype=jnp.float32)
    _, cache = model.prefill(params, {"tokens": toks[:, :S - 1]}, cache)
    # ring buffer is window-sized, not seq-sized
    assert cache["b0"]["k"].shape[2] == cfg.sliding_window
    logits_d, _ = model.decode(params, {"tokens": toks[:, S - 1:S]}, cache,
                               jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(logits_d[:, 0]), atol=2e-4)


def test_paper_models_smoke():
    for name, batch in [("paper-cnn",
                         {"x": jax.random.normal(KEY, (4, 28, 28, 1)),
                          "y": jnp.array([0, 1, 2, 3])}),
                        ("paper-mlp",
                         {"x": jax.random.normal(KEY, (4, 22)),
                          "y": jnp.array([0, 1, 2, 3])})]:
        model = build(ARCHS[name])
        params = model.init(KEY)
        loss, m = model.loss(params, batch)
        assert np.isfinite(float(loss))
