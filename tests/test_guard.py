"""NaN/Inf/absurd-norm client-update guard (aggregation.sanitize_updates)
and its wiring into the round loop: a crashed or hostile delivery must
yield a rejected contribution + gate-trust hit, never a poisoned model.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import aggregation


def _tree(k, seed=0, scale=1.0):
    key = jax.random.PRNGKey(seed)
    return {
        "w": scale * jax.random.normal(key, (k, 4, 3)),
        "b": scale * jax.random.normal(jax.random.fold_in(key, 1), (k, 5)),
    }


def test_clean_inputs_bitwise_passthrough():
    upd = _tree(6)
    mask = jnp.ones((6,))
    clean, m, rej = aggregation.sanitize_updates(upd, mask)
    assert float(rej.sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mask))
    for a, b in zip(jax.tree_util.tree_leaves(clean),
                    jax.tree_util.tree_leaves(upd)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("poison", [jnp.nan, jnp.inf, -jnp.inf])
def test_nonfinite_rows_rejected_and_zeroed(poison):
    upd = _tree(5)
    upd["w"] = upd["w"].at[2, 0, 0].set(poison)
    mask = jnp.ones((5,))
    clean, m, rej = aggregation.sanitize_updates(upd, mask)
    assert np.asarray(rej).tolist() == [0, 0, 1, 0, 0]
    assert np.asarray(m).tolist() == [1, 1, 0, 1, 1]
    assert np.all(np.asarray(clean["w"][2]) == 0.0)
    assert np.all(np.asarray(clean["b"][2]) == 0.0)
    assert np.all(np.isfinite(np.asarray(clean["w"])))


def test_absurd_norm_rejected_but_plausible_attacks_pass():
    upd = _tree(8)
    # a 10x sign-flip style row stays (legitimate attack scenarios are
    # the robust pipeline's job, not the guard's)...
    upd["w"] = upd["w"].at[1].set(-10.0 * upd["w"][1])
    # ...a 1e30 row does not
    upd["w"] = upd["w"].at[3].set(1e30)
    mask = jnp.ones((8,))
    _, m, rej = aggregation.sanitize_updates(upd, mask)
    assert np.asarray(rej).tolist() == [0, 0, 0, 1, 0, 0, 0, 0]
    assert float(m[1]) == 1.0


def test_norm_rule_disabled_keeps_finiteness_rule():
    upd = _tree(4)
    upd["w"] = upd["w"].at[0].set(1e30)
    upd["b"] = upd["b"].at[1, 0].set(jnp.nan)
    _, m, rej = aggregation.sanitize_updates(upd, jnp.ones((4,)),
                                             norm_mult=0)
    assert np.asarray(rej).tolist() == [0, 1, 0, 0]


def test_masked_out_rows_never_counted_rejected():
    upd = _tree(4)
    upd["w"] = upd["w"].at[0].set(jnp.nan)
    mask = jnp.asarray([0.0, 1.0, 1.0, 1.0])
    _, m, rej = aggregation.sanitize_updates(upd, mask)
    assert float(rej.sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mask))


@pytest.mark.parametrize("fused", [True, False])
@pytest.mark.parametrize("agg", ["fedavg", "median", "trimmed_mean", "krum"])
def test_aggregation_never_sees_the_poison(fused, agg):
    """Both the fused and reference paths produce a finite aggregate with
    NaN/Inf rows present — because the guard runs ahead of both."""
    k = 8
    upd = _tree(k)
    upd["w"] = upd["w"].at[0].set(jnp.nan)
    upd["b"] = upd["b"].at[1].set(jnp.inf)
    mask = jnp.ones((k,))
    cfg = FedConfig(n_clients=k, aggregator=agg, fused_agg=fused)
    clean, m, rej = aggregation.sanitize_updates(upd, mask)
    out = aggregation.aggregate(clean, jnp.ones((k,)), m, cfg)
    for leaf in jax.tree_util.tree_leaves(out):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("fused", [True, False])
def test_empty_after_rejection_cohort_yields_zero_update(fused):
    """ALL deliveries poisoned -> empty-mask aggregation -> zero update
    (the model simply holds for a round)."""
    k = 4
    upd = jax.tree_util.tree_map(lambda l: l * jnp.nan, _tree(k))
    mask = jnp.ones((k,))
    cfg = FedConfig(n_clients=k, aggregator="trimmed_mean", fused_agg=fused)
    clean, m, rej = aggregation.sanitize_updates(upd, mask)
    assert float(m.sum()) == 0.0 and float(rej.sum()) == k
    out = aggregation.aggregate(clean, jnp.ones((k,)), m, cfg)
    for leaf in jax.tree_util.tree_leaves(out):
        assert np.all(np.asarray(leaf) == 0.0)


def test_round_loop_rejects_and_penalizes_gate_trust():
    """End-to-end through make_round: a client shipping NaNs never
    reaches the model, and its gate_trust drops while honest clients'
    hold."""
    from repro.configs.registry import ARCHS
    from repro.core import fedfits
    from repro.data.pipeline import build_federation
    from repro.models.model import build

    K = 6
    cfg = FedConfig(n_clients=K, algorithm="fedavg", aggregator="fedavg",
                    local_epochs=1)
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(0, kind="tabular", n=600, n_clients=K,
                              batch_size=16, n_classes=10, sep=1.0,
                              dirichlet_alpha=1.0)
    mal = jnp.zeros((K,)).at[0].set(1.0)

    def nan_attack(upd, malicious, rng):
        return jax.tree_util.tree_map(
            lambda l: jnp.where(
                malicious.reshape((-1,) + (1,) * (l.ndim - 1)) > 0,
                jnp.full_like(l, jnp.nan), l), upd)

    state, hist = fedfits.run(model, cfg, fed.data_fn, 3,
                              jax.random.PRNGKey(0),
                              update_attack=nan_attack, malicious=mal,
                              driver="python")
    gt = np.asarray(state.gate_trust)
    assert all(float(h["guard_rejected"]) == 1.0 for h in hist)
    assert gt[0] < 0.8 and np.all(gt[1:] > 0.95)
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    # the rejected client's failure count tracks every rejected round
    assert float(np.asarray(state.clients.failures)[0]) == len(hist)


def test_guard_can_be_disabled():
    upd = _tree(3)
    upd["w"] = upd["w"].at[0].set(jnp.nan)
    cfg = FedConfig(n_clients=3, update_guard=False)
    assert cfg.update_guard is False  # config knob exists and plumbs
