"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, fitness, selection

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

floats01 = st.floats(0.0, 1.0, allow_nan=False)
lossf = st.floats(0.0, 20.0, allow_nan=False)


@given(st.lists(st.tuples(lossf, floats01, lossf, floats01),
                min_size=1, max_size=16))
def test_theta_always_in_first_quadrant(rows):
    gl, ga, ll, la = (jnp.asarray(x, jnp.float32) for x in zip(*rows))
    th = np.asarray(fitness.theta(gl, ga, ll, la))
    assert np.all(th >= -1e-6) and np.all(th <= np.pi / 2 + 1e-6)


@given(st.lists(floats01, min_size=2, max_size=16),
       st.floats(0.0, 1.0, allow_nan=False))
def test_threshold_never_exceeds_mean(scores, beta):
    s = jnp.asarray(scores, jnp.float32)
    t = float(fitness.threshold(s, beta))
    assert t <= float(s.mean()) + 1e-6


@given(st.integers(2, 12), st.integers(0, 1000))
def test_weighted_mean_in_convex_hull(k, seed):
    key = jax.random.PRNGKey(seed)
    upd = jax.random.normal(key, (k, 6))
    w = jax.random.uniform(jax.random.fold_in(key, 1), (k,)) + 0.01
    mask = jnp.ones((k,))
    out = np.asarray(aggregation.weighted_mean({"x": upd}, w, mask)["x"])
    lo, hi = np.asarray(upd).min(0), np.asarray(upd).max(0)
    assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)


@given(st.integers(3, 12), st.integers(0, 1000),
       st.floats(0.0, 0.3, allow_nan=False))
def test_trimmed_mean_bounded_and_permutation_invariant(k, seed, trim):
    key = jax.random.PRNGKey(seed)
    upd = jax.random.normal(key, (k, 5))
    mask = jnp.ones((k,))
    out = aggregation.trimmed_mean({"x": upd}, mask, trim)["x"]
    lo, hi = np.asarray(upd).min(0), np.asarray(upd).max(0)
    assert np.all(np.asarray(out) >= lo - 1e-5)
    assert np.all(np.asarray(out) <= hi + 1e-5)
    perm = jax.random.permutation(jax.random.fold_in(key, 2), k)
    out_p = aggregation.trimmed_mean({"x": upd[perm]}, mask, trim)["x"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p), atol=1e-5)


@given(st.integers(2, 12), st.integers(0, 1000))
def test_median_is_actual_masked_median(k, seed):
    key = jax.random.PRNGKey(seed)
    upd = jax.random.normal(key, (k, 4))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (k,)) > 0.3
            ).astype(jnp.float32)
    if float(mask.sum()) == 0:
        mask = mask.at[0].set(1.0)
    out = np.asarray(aggregation.median({"x": upd}, mask)["x"])
    sel = np.asarray(upd)[np.asarray(mask) > 0]
    np.testing.assert_allclose(out, np.median(sel, axis=0), atol=1e-5)


@given(st.integers(2, 16), st.floats(0.05, 1.0), st.integers(0, 100))
def test_fedrand_selects_exactly_ceil_ck(k, c, seed):
    avail = jnp.ones((k,))
    m = selection.fedrand_select(avail, c, jax.random.PRNGKey(seed))
    assert float(m.sum()) == np.ceil(c * k)


@given(st.integers(2, 16), st.integers(0, 100))
def test_selection_subset_of_available(k, seed):
    key = jax.random.PRNGKey(seed)
    scores = jax.random.uniform(key, (k,))
    avail = (jax.random.uniform(jax.random.fold_in(key, 1), (k,)) > 0.4
             ).astype(jnp.float32)
    if float(avail.sum()) == 0:
        avail = avail.at[0].set(1.0)
    mask = selection.fedfits_select(scores, 0.2, avail,
                                    jax.random.fold_in(key, 2),
                                    explore_eps=0.3, floor_prob=0.3)
    assert np.all(np.asarray(mask) <= np.asarray(avail))


@given(st.integers(1, 10), st.integers(0, 100))
def test_dynamic_alpha_bounds(k, seed):
    key = jax.random.PRNGKey(seed)
    q = jax.random.uniform(key, (k,))
    th = jax.random.uniform(jax.random.fold_in(key, 1), (k,))
    a = float(fitness.dynamic_alpha(q, th))
    assert 0.0 <= a <= 1.0


def _select(algo, avail, key):
    scores = jax.random.uniform(jax.random.fold_in(key, 1), avail.shape)
    losses = jax.random.uniform(jax.random.fold_in(key, 2), avail.shape)
    if algo == "fedfits":
        return selection.fedfits_select(scores, 0.2, avail,
                                        jax.random.fold_in(key, 3),
                                        explore_eps=0.3, floor_prob=0.3)
    if algo == "fedavg":
        return selection.fedavg_select(avail)
    if algo == "fedrand":
        return selection.fedrand_select(avail, 0.5,
                                        jax.random.fold_in(key, 3))
    return selection.fedpow_select(losses, avail, 0.8, 3,
                                   jax.random.fold_in(key, 3))


@pytest.mark.parametrize("algo", ["fedfits", "fedavg", "fedrand", "fedpow"])
@given(k=st.integers(2, 16), seed=st.integers(0, 200),
       p=st.floats(0.0, 1.0, allow_nan=False))
def test_no_algorithm_selects_unavailable_clients(algo, k, seed, p):
    """Straggler faults shrink `avail`; no selection algorithm may ever
    route an unavailable client into the team mask."""
    key = jax.random.PRNGKey(seed)
    avail = (jax.random.uniform(key, (k,)) < p).astype(jnp.float32)
    team = np.asarray(_select(algo, avail, key))
    assert np.all(team * (1.0 - np.asarray(avail)) == 0.0)


@pytest.mark.parametrize("algo", ["fedfits", "fedavg", "fedrand", "fedpow"])
@given(k=st.integers(1, 16), seed=st.integers(0, 200))
def test_all_unavailable_round_selects_nobody(algo, k, seed):
    avail = jnp.zeros((k,), jnp.float32)
    team = np.asarray(_select(algo, avail, jax.random.PRNGKey(seed)))
    assert float(team.sum()) == 0.0


# ---------------------------------------------------------------------------
# buffered-async engine invariants (core/async_engine.py)
# ---------------------------------------------------------------------------
@given(r=st.integers(1, 48), seed=st.integers(0, 500),
       decay=st.floats(0.05, 1.0, allow_nan=False))
def test_async_delivery_weights_are_convex(r, seed, decay):
    """The staleness-weighted buffer's aggregation weights always form a
    convex combination over the round's delivery set: entries in [0, 1],
    summing to 1 (or all-zero for an empty round) — stale evidence can
    shrink but never flip or inflate a contribution."""
    from repro.core import async_engine
    key = jax.random.PRNGKey(seed)
    n_k = jax.random.uniform(key, (r,), minval=0.0, maxval=100.0)
    trust = jax.random.uniform(jax.random.fold_in(key, 1), (r,))
    age = jax.random.randint(jax.random.fold_in(key, 2), (r,), 0, 5)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (r,)) > 0.5
            ).astype(jnp.float32)
    w = np.asarray(async_engine.delivery_weights(
        n_k, trust, mask, age, staleness_decay=decay))
    assert np.all(w >= 0.0) and np.all(w <= 1.0 + 1e-6)
    total = w.sum()
    assert abs(total - 1.0) < 1e-4 or total < 1e-6
    assert np.all(w * (1.0 - np.asarray(mask)) == 0.0)  # masked-out = 0


@given(age=st.integers(0, 6), decay=st.floats(0.05, 1.0, allow_nan=False))
def test_async_staleness_monotone(age, decay):
    """An older buffered delivery never outweighs a fresh one of the same
    owner (same n_k, same trust): decay^age is non-increasing in age."""
    from repro.core import async_engine
    n_k = jnp.asarray([10.0, 10.0])
    trust = jnp.asarray([0.8, 0.8])
    ages = jnp.asarray([0, age])
    w = np.asarray(async_engine.delivery_weights(
        n_k, trust, jnp.ones((2,)), ages, staleness_decay=decay))
    assert w[0] >= w[1] - 1e-6


@given(c=st.integers(1, 32), retries=st.integers(0, 4))
def test_async_buffer_capacity_covers_worst_case(c, retries):
    """B = C * max_retries: a cohort that is late EVERY round for its full
    retry budget always fits (no eviction before retries run out)."""
    from repro.configs.base import FedConfig
    from repro.core import async_engine
    cfg = FedConfig(n_clients=c, async_max_retries=retries)
    b = async_engine.buffer_capacity(cfg)
    assert b >= max(c * retries, 1)


@given(k=st.integers(2, 12), bad=st.integers(0, 11),
       seed=st.integers(0, 500))
def test_guard_rejects_exactly_the_poisoned_row(k, bad, seed):
    """sanitize_updates: clean random cohorts pass through bit-identically;
    poisoning one row's single coordinate rejects exactly that row."""
    bad = bad % k
    key = jax.random.PRNGKey(seed)
    upd = {"w": jax.random.normal(key, (k, 5))}
    mask = jnp.ones((k,))
    clean, m, rej = aggregation.sanitize_updates(upd, mask)
    np.testing.assert_array_equal(np.asarray(clean["w"]),
                                  np.asarray(upd["w"]))
    assert float(rej.sum()) == 0.0
    poisoned = {"w": upd["w"].at[bad, 0].set(jnp.nan)}
    _, m2, rej2 = aggregation.sanitize_updates(poisoned, mask)
    expect = np.zeros(k); expect[bad] = 1.0
    np.testing.assert_array_equal(np.asarray(rej2), expect)
    np.testing.assert_array_equal(np.asarray(m2), 1.0 - expect)
