"""Scenario registry + engine: registry validity, fairness metric units,
gate-trust EWMA behavior (exact no-op when never gated; separates
malicious from honest under attack), and the end-to-end robustness
regression — adaptive attacks measurably degrade plain fedavg while the
threat-sized robust aggregators hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import attacks, fairness, fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build
from repro.scenarios import (SCENARIOS, Scenario, all_scenarios, get,
                             run_scenario, smoke_grid)
from repro.scenarios import registry as screg

K = 10


# ------------------------------------------------------------------
# registry
# ------------------------------------------------------------------
def test_registry_cells_are_well_formed():
    for name, sc in all_scenarios().items():
        assert sc.name == name
        assert sc.attack in screg.ATTACKS
        assert sc.aggregator in ("fedavg", "median", "trimmed_mean", "krum")
        assert sc.algorithm in ("fedfits", "fedavg", "fedrand", "fedpow")
        assert 0.0 <= sc.mal_frac < 0.5
        cfg = sc.fed_config(K)         # must construct a valid FedConfig
        assert isinstance(cfg, FedConfig)


def test_registry_defense_sized_to_threat():
    cfg = get("alie_trimmed").fed_config(K)
    n_mal = int(round(0.3 * K))
    # trimmed mean must trim >= n_mal rows per side, krum_f covers them
    assert int(cfg.trim_frac * K) >= n_mal
    assert get("alie_krum").fed_config(K).krum_f == n_mal


def test_smoke_grid_is_the_full_matrix():
    grid = smoke_grid()
    # 3 attacks x 3 aggregators x dropout on/off + 4 buffered-async cells
    assert len(grid) == 22
    sync = {n: g for n, g in grid.items() if not g.async_mode}
    assert len(sync) == 18
    assert set(g.attack for g in sync.values()) \
        == {"gate_aware", "alie", "none"}
    assert set(g.aggregator for g in sync.values()) \
        == {"trimmed_mean", "krum", "fedavg"}
    assert sum(g.faults.dropout_active for g in sync.values()) == 9
    asyn = {n: g for n, g in grid.items() if g.async_mode}
    assert len(asyn) == 4
    assert all(g.faults.stragglers_active for g in asyn.values())
    # attacked async cells make the colluders the chronic stragglers
    assert all((g.straggler_rows == "head") == (g.attack != "none")
               for g in asyn.values())


def test_get_unknown_scenario_raises_with_known_names():
    with pytest.raises(KeyError, match="alie_fedavg"):
        get("no_such_cell")


def test_dropout_compression_cell_present():
    sc = get("gate_aware_int8_dropout")
    assert sc.compress == "int8" and sc.faults.dropout_active \
        and sc.attack == "gate_aware"


# ------------------------------------------------------------------
# fairness units
# ------------------------------------------------------------------
def test_accuracy_variance_constant_is_zero():
    acc = jnp.full((8,), 0.7)
    assert float(fairness.accuracy_variance(acc)) == 0.0
    mask = jnp.array([1, 1, 0, 0, 1, 1, 0, 0], jnp.float32)
    hetero = jnp.where(mask > 0, 0.7, 99.0)   # masked-out junk ignored
    assert float(fairness.accuracy_variance(hetero, mask)) == 0.0


def test_worst_decile_picks_the_tail():
    acc = jnp.array([0.9] * 19 + [0.1])
    # ceil(0.1 * 20) = 2 worst clients -> mean(0.1, 0.9)
    np.testing.assert_allclose(float(fairness.worst_decile(acc)), 0.5,
                               atol=1e-6)
    mask = jnp.ones((20,)).at[19].set(0.0)    # mask out the straggler
    np.testing.assert_allclose(
        float(fairness.worst_decile(acc, mask)), 0.9, atol=1e-6)


def test_participation_gini_even_vs_monopoly():
    assert float(fairness.participation_gini(jnp.full((10,), 5.0))) \
        == pytest.approx(0.0, abs=1e-6)
    mono = jnp.zeros((10,)).at[0].set(50.0)
    assert float(fairness.participation_gini(mono)) \
        == pytest.approx(0.9, abs=1e-6)
    assert float(fairness.participation_gini(jnp.zeros((10,)))) == 0.0


# ------------------------------------------------------------------
# gate-trust EWMA
# ------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_setup():
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(0, kind="tabular", n=600, n_clients=6,
                              batch_size=16, n_classes=10)
    return model, fed


def test_gate_trust_noop_when_never_gated(small_setup):
    """cosine_outlier_thresh below -1 can never gate anyone: gate_trust
    must stay exactly 1 and trust_in_fitness on/off must be bitwise
    identical — the EWMA is behavior-preserving for clean runs."""
    model, fed = small_setup
    runs = {}
    for tif in (True, False):
        cfg = FedConfig(n_clients=6, algorithm="fedfits",
                        cosine_outlier_thresh=-1.1, trust_in_fitness=tif)
        runs[tif] = fedfits.run(model, cfg, fed.data_fn, 3,
                                jax.random.PRNGKey(2))
    s_on, h_on = runs[True]
    s_off, h_off = runs[False]
    np.testing.assert_array_equal(np.asarray(s_on.gate_trust), 1.0)
    for a, b in zip(jax.tree_util.tree_leaves(s_on.params),
                    jax.tree_util.tree_leaves(s_off.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for r_on, r_off in zip(h_on, h_off):
        np.testing.assert_array_equal(np.asarray(r_on["gated_frac"]),
                                      np.asarray(r_off["gated_frac"]))
        assert float(r_on["gated_frac"]) == 0.0


def test_gate_trust_separates_malicious_from_honest():
    """Colluders pushing the exact anti-honest-mean direction are what
    the cosine gate is built to catch: their cosine-to-aggregate pins at
    ~-1 while honest clients stay positive, so the EWMA demotes exactly
    the malicious rows and leaves honest trust untouched."""
    model = build(ARCHS["paper-mlp"])
    # harder separation than the easy-mode default so honest updates
    # keep pointing somewhere real for more than one round
    fed, _ = build_federation(0, kind="tabular", n=600, n_clients=6,
                              batch_size=16, n_classes=10, sep=0.8,
                              dirichlet_alpha=1.0)
    malicious = jnp.zeros((6,)).at[jnp.arange(2)].set(1.0)

    def update_attack(upd, mal, rng):
        wh = (1.0 - mal) / (1.0 - mal).sum()

        def per_leaf(u):
            mu = jnp.tensordot(wh.astype(u.dtype), u, axes=(0, 0))
            m = mal.reshape((-1,) + (1,) * (u.ndim - 1)).astype(u.dtype)
            return u * (1 - m) + (-10.0 * mu)[None] * m

        return jax.tree_util.tree_map(per_leaf, upd)

    cfg = FedConfig(n_clients=6, algorithm="fedavg",
                    aggregator="trimmed_mean", trim_frac=0.34,
                    trust_decay=0.7, local_epochs=2, local_lr=0.2)
    state, hist = fedfits.run(model, cfg, fed.data_fn, 4,
                              jax.random.PRNGKey(3),
                              update_attack=update_attack,
                              malicious=malicious)
    gt = np.asarray(state.gate_trust)
    assert gt[:2].max() < 0.95          # malicious demoted
    assert gt[2:].min() > 0.99          # honest untouched
    assert gt[:2].max() < gt[2:].min()
    assert any(float(h["gated_frac"]) > 0 for h in hist)


# ------------------------------------------------------------------
# engine
# ------------------------------------------------------------------
def test_engine_smoke_hardest_cell():
    """gate_aware attacker + int8 uplink + dropout through the scan
    driver — the cell that touches every subsystem at once."""
    summary, hist = run_scenario("gate_aware_int8_dropout", n_clients=6,
                                 n_rounds=2, n=400, chunk_rounds=2)
    assert summary["name"] == "robustness/gate_aware_int8_dropout"
    assert summary["compress"] == "int8" and summary["faults_active"]
    assert summary["rounds"] == 2 and len(hist) == 2
    assert 0.0 <= summary["final_acc"] <= 1.0
    assert 0.0 <= summary["final_trigger_acc"] <= 1.0
    assert summary["cost_bytes_up"] > 0
    for key in ("fair_acc_var", "fair_worst_decile", "fair_part_gini",
                "gate_trust_malicious", "gate_trust_honest"):
        assert np.isfinite(summary[key])


def test_engine_runs_are_deterministic():
    a, _ = run_scenario("alie_trimmed", n_clients=6, n_rounds=2, n=400)
    b, _ = run_scenario("alie_trimmed", n_clients=6, n_rounds=2, n=400)
    for k in ("final_acc", "best_acc", "final_trigger_acc",
              "fair_part_gini", "gate_trust_malicious"):
        assert a[k] == b[k]


# ------------------------------------------------------------------
# the regression matrix itself (acceptance criterion): adaptive attacks
# measurably degrade plain fedavg; threat-sized robust aggregators hold
# ------------------------------------------------------------------
_CELLS = ["clean_fedavg", "alie_fedavg", "gate_aware_fedavg",
          "clean_trimmed", "alie_trimmed", "gate_aware_trimmed",
          "clean_krum", "gate_aware_krum"]


@pytest.fixture(scope="module")
def matrix():
    out = {}
    for name in _CELLS:
        sc = SCENARIOS[name] if name != "clean_krum" else Scenario(
            "clean_krum", "no attack, krum", attack="none",
            aggregator="krum")
        summary, _ = run_scenario(sc, n_clients=K, n_rounds=6, n=600,
                                  seed=0)
        out[name] = summary["best_acc"]
    return out


def test_adaptive_attacks_break_plain_fedavg(matrix):
    assert matrix["clean_fedavg"] - matrix["alie_fedavg"] >= 0.2
    assert matrix["clean_fedavg"] - matrix["gate_aware_fedavg"] >= 0.2


def test_robust_aggregators_hold_under_adaptive_attack(matrix):
    # within a small margin of their own clean baseline...
    assert matrix["alie_trimmed"] >= matrix["clean_trimmed"] - 0.3
    assert matrix["gate_aware_trimmed"] >= matrix["clean_trimmed"] - 0.3
    assert matrix["gate_aware_krum"] >= matrix["clean_krum"] - 0.3
    # ...and strictly better than the undefended mean under the same
    # attack (the defense buys something)
    assert matrix["alie_trimmed"] > matrix["alie_fedavg"] + 0.05
    assert matrix["gate_aware_trimmed"] \
        > matrix["gate_aware_fedavg"] + 0.05
    assert matrix["gate_aware_krum"] > matrix["gate_aware_fedavg"] + 0.05
