"""Buffered-async round engine (core/async_engine.py): scan==python bit
parity with the delivery buffer + retry/backoff + faults active, billing
invariants (billed-but-lost), graceful degradation under 30% stragglers,
and the late-poison evasion channel."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import async_engine, attacks, fedfits
from repro.core.faults import FaultConfig
from repro.data.pipeline import build_federation
from repro.models.model import build

_LATE = FaultConfig(straggler_frac=0.3, straggler_delay=3.0,
                    base_delay=0.3)


def _cfg(c=8, m=24, **kw):
    base = dict(n_clients=c, population=m, algorithm="fedavg",
                aggregator="trimmed_mean", local_epochs=1, local_lr=0.2,
                async_max_retries=2, staleness_decay=0.5)
    base.update(kw)
    return FedConfig(**base)


def _setup(seed=0, m=24, n=600):
    model = build(ARCHS["paper-mlp"])
    fed, server_test = build_federation(
        seed, kind="tabular", n=n, n_clients=m, batch_size=16,
        n_classes=10, sep=1.0, dirichlet_alpha=1.0)

    @jax.jit
    def eval_fn(params):
        _, met = model.loss(params, server_test)
        return {"test_acc": met["acc"]}

    return model, fed, eval_fn


def _leaves(state):
    return [l for l in jax.tree_util.tree_leaves(state)
            if hasattr(l, "shape")]


def test_scan_python_bit_parity_full_stack():
    """The acceptance bit: chunked-scan and per-round-jit drivers are
    bit-for-bit equal with the buffer, retry/backoff, fault injection AND
    the stateful cross-round attacker all riding the carry."""
    model, fed, _ = _setup(0)
    cfg = _cfg()
    mal = jnp.zeros((24,)).at[jnp.arange(4)].set(1.0)
    kw = dict(batch_size=16, update_attack=attacks.CrossRoundGateAware(cfg),
              malicious=mal, faults=_LATE, straggler_rows="head")
    st_p, h_p = async_engine.run_async(
        model, cfg, fed.data, 6, jax.random.PRNGKey(0), driver="python",
        **kw)
    st_s, h_s = async_engine.run_async(
        model, cfg, fed.data, 6, jax.random.PRNGKey(0), driver="scan",
        chunk_rounds=3, **kw)
    assert len(h_p) == len(h_s) == 6
    for rp, rs in zip(h_p, h_s):
        assert set(rp) == set(rs)
        for k in rp:
            np.testing.assert_array_equal(
                np.asarray(rp[k]), np.asarray(rs[k]), err_msg=f"round {k}")
    for a, b in zip(_leaves(st_p), _leaves(st_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the fault injection actually exercised the buffer path
    assert sum(float(r["buffered"]) for r in h_p) > 0


def test_billing_once_per_computed_round():
    """Deterministic twins: a straggler-ridden run and a fault-free run
    bill IDENTICALLY — C client-rounds per round, work billed when
    computed, retried deliveries never re-billed, abandoned work billed
    but lost (the PR-5 dropout semantics at the async boundary)."""
    model, fed, _ = _setup(1)
    cfg = _cfg()
    st_f, h_f = async_engine.run_async(
        model, cfg, fed.data, 5, jax.random.PRNGKey(1), driver="python",
        batch_size=16, faults=_LATE)
    st_c, _ = async_engine.run_async(
        model, cfg, fed.data, 5, jax.random.PRNGKey(1), driver="python",
        batch_size=16)
    assert float(st_f.cost_client_rounds) == 5 * cfg.n_clients
    assert float(st_f.cost_client_rounds) == float(st_c.cost_client_rounds)
    assert float(st_f.cost_bytes_up) == float(st_c.cost_bytes_up)
    # ...even though the faulty run abandoned/buffered real work
    assert sum(float(r["buffered"]) + float(r["abandoned"])
               for r in h_f) > 0


def test_retry_exhaustion_decays_trust_and_routes_around():
    """Chronic stragglers with no retry budget: every late delivery is
    abandoned -> failures bump, trust decays, and the Gumbel-top-d
    scheduler samples them less (graceful degradation routing)."""
    model, fed, _ = _setup(2)
    cfg = _cfg(c=6, m=20, async_max_retries=0, async_deadline=0.5)
    fl = FaultConfig(straggler_frac=0.3, straggler_delay=50.0,
                     base_delay=0.01)
    state, hist = async_engine.run_async(
        model, cfg, fed.data, 12, jax.random.PRNGKey(2), driver="python",
        batch_size=16, faults=fl, straggler_rows="head")
    st = state.clients
    n_s = int(round(0.3 * 20))                     # straggler rows (head)
    fails = np.asarray(st.failures)
    trust = np.asarray(st.trust)
    sel = np.asarray(st.cum_selected)
    assert fails[:n_s].sum() > 0 and fails[n_s:].sum() == 0
    assert trust[:n_s].mean() < trust[n_s:].mean()
    # selection pressure: late-round cohorts avoid the flaky head rows
    assert sel[:n_s].mean() < sel[n_s:].mean()
    assert sum(float(r["abandoned"]) for r in hist) == fails.sum()


def test_buffer_retry_delivers_late_work():
    """With a retry budget, chronically-delayed work eventually lands
    through the buffer (buffered rows > 0 and later rounds deliver more
    rows than the cohort's on-time count)."""
    model, fed, _ = _setup(3)
    cfg = _cfg(async_max_retries=2, async_backoff=2.0)
    _, hist = async_engine.run_async(
        model, cfg, fed.data, 8, jax.random.PRNGKey(3), driver="python",
        batch_size=16, faults=_LATE)
    buffered = sum(float(r["buffered"]) for r in hist)
    assert buffered > 0
    late_deliveries = sum(
        float(r["delivered"]) - float(r["on_time_frac"]) * cfg.n_clients
        for r in hist)
    assert late_deliveries > 0                    # some due rows landed


@pytest.mark.parametrize("seed", [0, 1])
def test_graceful_degradation_within_tolerance(seed):
    """Acceptance criterion: at 30% chronic stragglers the buffered-async
    engine's best accuracy stays within 0.05 of the synchronous
    (fault-free, full-participation) baseline."""
    model, fed_pop, eval_fn = _setup(seed, m=24, n=1200)
    cfg = _cfg(local_epochs=2)
    _, h_async = async_engine.run_async(
        model, cfg, fed_pop.data, 10, jax.random.PRNGKey(seed + 1),
        eval_fn=eval_fn, batch_size=32, faults=_LATE, driver="scan",
        chunk_rounds=5)

    fed_sync, server_test = build_federation(
        seed, kind="tabular", n=1200, n_clients=8, batch_size=32,
        n_classes=10, sep=1.0, dirichlet_alpha=1.0)
    sync_cfg = FedConfig(n_clients=8, algorithm="fedavg",
                         aggregator="trimmed_mean", local_epochs=2,
                         local_lr=0.2)

    @jax.jit
    def eval_sync(params):
        _, met = model.loss(params, server_test)
        return {"test_acc": met["acc"]}

    _, h_sync = fedfits.run(model, sync_cfg, fed_sync.data_fn, 10,
                            jax.random.PRNGKey(seed + 1),
                            eval_fn=eval_sync, driver="scan",
                            chunk_rounds=5)
    best_async = max(float(r["test_acc"]) for r in h_async)
    best_sync = max(float(r["test_acc"]) for r in h_sync)
    assert best_async >= best_sync - 0.05, (best_async, best_sync)


def test_late_poison_at_stale_weight_does_not_evade():
    """Satellite 2's evasion channel: colluders who are also the chronic
    stragglers deliver their cross-round poison LATE through the retry
    buffer at staleness-decayed weight — the threat-sized trimmed mean
    must hold (accuracy does not collapse vs the clean async run)."""
    from repro.scenarios import run_scenario
    clean, _ = run_scenario("async_hetero", n_clients=8, n_rounds=6,
                            n=800, driver="python")
    poison, _ = run_scenario("async_late_poison", n_clients=8, n_rounds=6,
                             n=800, driver="python")
    assert poison["best_acc"] > 0.55
    assert poison["best_acc"] > clean["best_acc"] - 0.2
    # threat-sized defense: trim covers the declared colluder fraction
    assert poison["aggregator"] == "trimmed_mean"


def test_compression_unsupported():
    # the combination fails fast at config build with the launch-flag fix
    with pytest.raises(ValueError, match="buffered-async"):
        _cfg(compress="int8")
    # and the engine itself rejects duck-typed configs that sneak past
    model, fed, _ = _setup(4)
    cfg = types.SimpleNamespace(compress="int4")
    with pytest.raises(ValueError, match="dense-uplink"):
        async_engine.make_async_round(model, cfg, fed.data)


def test_empty_guarded_round_holds_model():
    """Every delivery NaN-poisoned: the guard empties the round and the
    global model simply holds (no NaN ever reaches the params)."""
    model, fed, _ = _setup(5)
    cfg = _cfg(async_max_retries=0)
    mal = jnp.ones((24,))

    def nan_attack(upd, malicious, rng):
        return jax.tree_util.tree_map(
            lambda l: jnp.full_like(l, jnp.nan), upd)

    state, hist = async_engine.run_async(
        model, cfg, fed.data, 3, jax.random.PRNGKey(5), driver="python",
        batch_size=16, update_attack=nan_attack, malicious=mal)
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    assert all(float(r["guard_rejected"]) == cfg.n_clients for r in hist)
    assert all(float(r["delivered"]) == 0.0 for r in hist)
