"""End-to-end behaviour tests: the paper's system-level claims at the
paper's own scale (SimEngine, synthetic non-IID data)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import attacks, fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build

K = 8


@pytest.fixture(scope="module")
def setup():
    model = build(ARCHS["paper-mlp"])
    fed, test = build_federation(0, kind="tabular", n=1500, n_clients=K,
                                 batch_size=32, n_classes=22, n_features=22)

    @jax.jit
    def eval_fn(params):
        l, m = model.loss(params, test)
        return {"test_loss": l, "test_acc": m["acc"]}

    return model, fed, eval_fn


def _run(model, fed, eval_fn, algo, rounds=12, attack=False, **kw):
    malicious = jnp.zeros((K,)).at[jnp.arange(2)].set(1.0) if attack else None

    def data_attack(data, mal, rng):
        return {"y": attacks.label_flip(data["y"], 22, mal)}

    cfg = FedConfig(n_clients=K, algorithm=algo, local_epochs=2,
                    local_lr=0.05, msl=4, pft=2, **kw)
    state, hist = fedfits.run(
        model, cfg, fed.data_fn, rounds, jax.random.PRNGKey(2),
        eval_fn=eval_fn,
        data_attack=data_attack if attack else None,
        malicious=malicious)
    return state, hist


def test_fedfits_converges_normal_mode(setup):
    model, fed, eval_fn = setup
    state, hist = _run(model, fed, eval_fn, "fedfits")
    assert hist[-1]["test_acc"] > 0.8, [h["test_acc"] for h in hist]


def test_fedfits_beats_fedavg_under_attack(setup):
    """The paper's headline claim (Tables III/V)."""
    model, fed, eval_fn = setup
    _, h_avg = _run(model, fed, eval_fn, "fedavg", attack=True)
    _, h_fit = _run(model, fed, eval_fn, "fedfits", attack=True)
    best_avg = max(h["test_acc"] for h in h_avg)
    best_fit = max(h["test_acc"] for h in h_fit)
    assert best_fit >= best_avg - 0.02, (best_fit, best_avg)
    # and the team excludes poisoned clients most of the time
    team_rounds = np.stack([h["team"] for h in h_fit[2:]])
    mal_rate = team_rounds[:, :2].mean()
    honest_rate = team_rounds[:, 2:].mean()
    assert mal_rate < honest_rate


def test_fedfits_cheaper_than_fedavg(setup):
    """Slotted selection bills fewer client-rounds (paper: execution time)."""
    model, fed, eval_fn = setup
    s_avg, _ = _run(model, fed, eval_fn, "fedavg")
    s_fit, _ = _run(model, fed, eval_fn, "fedfits")
    assert float(s_fit.cost_client_rounds) < float(s_avg.cost_client_rounds)


def test_baselines_run(setup):
    model, fed, eval_fn = setup
    for algo in ["fedrand", "fedpow"]:
        _, hist = _run(model, fed, eval_fn, algo, rounds=6)
        assert np.isfinite(hist[-1]["test_acc"])


def test_dynamic_alpha_changes_over_rounds(setup):
    model, fed, eval_fn = setup
    _, hist = _run(model, fed, eval_fn, "fedfits", dynamic_alpha=True)
    alphas = {round(float(h["alpha"]), 3) for h in hist}
    assert len(alphas) >= 1  # defined every round
    assert all(0.0 <= a <= 1.0 for a in alphas)


def test_robust_aggregator_under_model_poison(setup):
    model, fed, eval_fn = setup
    malicious = jnp.zeros((K,)).at[jnp.arange(2)].set(1.0)

    def update_attack(upd, mal, rng):
        return attacks.sign_flip(upd, mal, scale=10.0)

    cfg = FedConfig(n_clients=K, algorithm="fedfits", local_epochs=2,
                    local_lr=0.05, aggregator="trimmed_mean")
    state, hist = fedfits.run(model, cfg, fed.data_fn, 10,
                              jax.random.PRNGKey(3), eval_fn=eval_fn,
                              update_attack=update_attack,
                              malicious=malicious)
    assert hist[-1]["test_acc"] > 0.5, [h["test_acc"] for h in hist]
