"""Compressed client->server transport subsystem (repro/comm/): codec
round-trip error bounds, EF residual contraction, fused dequant-into-
aggregation parity (bit-exact vs decode-then-aggregate, quantization
error vs the dense fp32 oracle, incl. the mesh-sharded path), empty-
cohort x compression interaction, and the measured-bytes accounting
through ``fedfits.run``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import codecs, error_feedback
from repro.comm.kernels import comm_codecs as dq
from repro.configs.base import FedConfig
from repro.core import aggregation
from repro.kernels.robust_pipeline import fused_aggregate_tree

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

KEY = jax.random.PRNGKey(0)
AGGS = ["fedavg", "median", "trimmed_mean", "krum"]


def _tree(c, key=KEY):
    """Multi-leaf, ragged, tiny-bias tree (the shapes that stress the
    segment table + quant-block alignment)."""
    return {"a": jax.random.normal(key, (c, 13, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (c, 301)),
            "c": jax.random.normal(jax.random.fold_in(key, 2), (c, 5)),
            "d": jax.random.normal(jax.random.fold_in(key, 3), (c, 512))}


# ---------------------------------------------------------------- codecs --
@pytest.mark.parametrize("name,levels", [("int8", 127.0), ("int4", 7.0)])
def test_quant_roundtrip_error_bound(name, levels):
    """Blockwise absmax quantization: per-coordinate error <= half a
    quantization step of its OWN block (s/2 = blockmax/levels/2)."""
    c, qblk = 6, 64
    tree = _tree(c)
    codec = codecs.Codec(name, qblk=qblk)
    dec = codec.decode_tree(codec.encode_tree(tree), tree)
    for k in tree:
        x = np.asarray(tree[k], np.float32).reshape(c, -1)
        d = np.asarray(dec[k], np.float32).reshape(c, -1)
        n = x.shape[1]
        nq = -(-n // qblk)
        xp = np.pad(x, ((0, 0), (0, nq * qblk - n))).reshape(c, nq, qblk)
        step = np.abs(xp).max(-1) / levels            # (c, nq)
        bound = np.repeat(step, qblk, axis=1)[:, :n]
        assert np.all(np.abs(x - d) <= 0.5 * bound + 1e-7), k


def test_int4_pack_unpack_exact():
    q = jax.random.randint(KEY, (3, 11), -7, 8, jnp.int8)
    p = codecs.pack_int4(q)
    assert p.dtype == jnp.uint8 and p.shape == (3, 6)
    np.testing.assert_array_equal(np.asarray(codecs.unpack_int4(p, 11)),
                                  np.asarray(q))


def test_bit_pack_unpack_exact():
    b = (jax.random.uniform(KEY, (4, 21)) > 0.5).astype(jnp.uint8)
    p = codecs.pack_bits(b)
    assert p.dtype == jnp.uint8 and p.shape == (4, 3)
    np.testing.assert_array_equal(np.asarray(codecs.unpack_bits(p, 21)),
                                  np.asarray(b))


def test_signsgd_roundtrip_sign_and_magnitude():
    c, qblk = 5, 32
    tree = {"w": jax.random.normal(KEY, (c, 100)) + 0.01}
    codec = codecs.Codec("signsgd", qblk=qblk)
    dec = codec.decode_tree(codec.encode_tree(tree), tree)
    x = np.asarray(tree["w"]); d = np.asarray(dec["w"])
    # signs preserved everywhere (no exact zeros in the input)
    assert np.all(np.sign(d) == np.sign(x))
    # magnitude = per-block mean |x| (tail block over its 100-96=4 reals)
    blocks = np.abs(x[:, :96]).reshape(c, 3, qblk).mean(-1)
    np.testing.assert_allclose(np.abs(d[:, :96]).reshape(c, 3, qblk),
                               np.repeat(blocks[:, :, None], qblk, 2),
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.abs(d[:, 96:]),
        np.broadcast_to(np.abs(x[:, 96:]).mean(-1, keepdims=True), (c, 4)),
        rtol=1e-5)


def test_signsgd_majority_vote_defeats_minority_flippers():
    c = 9
    honest = jnp.ones((c, 64)) * 0.5
    upd = honest.at[0].set(-0.5).at[1].set(-0.5)      # 2/9 sign-flipped
    enc = codecs.Codec("signsgd", qblk=32).encode(upd)
    out = codecs.majority_vote(enc, 64, 32, jnp.ones((c,)))
    assert np.all(np.asarray(out) > 0.0)              # majority wins
    np.testing.assert_allclose(np.asarray(out), 0.5, rtol=1e-5)


def test_topk_keeps_largest_and_zeros_rest():
    c, n, frac = 4, 200, 0.1
    x = {"w": jax.random.normal(KEY, (c, n))}
    codec = codecs.Codec("topk", topk_frac=frac)
    enc_leaf = jax.tree_util.tree_flatten(
        codec.encode_tree(x), is_leaf=codecs.is_encoded)[0][0]
    dec = codec.decode_tree(codec.encode_tree(x), x)
    k = codec._k(n)
    assert enc_leaf.val.shape == (c, k) == enc_leaf.idx.shape
    xa, da = np.asarray(x["w"]), np.asarray(dec["w"])
    for i in range(c):
        nz = np.nonzero(da[i])[0]
        assert len(nz) == k
        np.testing.assert_array_equal(da[i][nz], xa[i][nz])  # kept exact
        # kept coords are the k largest magnitudes
        assert np.abs(xa[i][nz]).min() >= \
            np.sort(np.abs(xa[i]))[-k] - 1e-7


def test_randk_fallback_needs_rng_and_is_unbiased():
    c, n = 3, 150
    x = {"w": jax.random.normal(KEY, (c, n))}
    codec = codecs.Codec("randk", topk_frac=0.2)
    with pytest.raises(ValueError):
        codec.encode_tree(x)
    dec = codec.decode_tree(codec.encode_tree(x, rng=KEY), x)
    da, xa = np.asarray(dec["w"]), np.asarray(x["w"])
    k = codec._k(n)
    for i in range(c):
        nz = np.nonzero(da[i])[0]
        assert len(nz) == k                           # k distinct coords
        # kept values importance-scaled by n/k -> E[dec] = x (unbiased)
        np.testing.assert_allclose(da[i][nz], xa[i][nz] * n / k, rtol=1e-6)
    # unbiasedness over the index draw: the mean of many independent
    # decodes converges on the true vector
    acc = np.zeros_like(xa)
    reps = 200
    for r in range(reps):
        d = codec.decode_tree(
            codec.encode_tree(x, rng=jax.random.fold_in(KEY, r)), x)
        acc += np.asarray(d["w"])
    # per-coord std of the mean is ~|x| * sqrt((n/k - 1) / reps) ~ 0.14|x|;
    # atol sits at ~3.5 sigma of the largest coords
    np.testing.assert_allclose(acc / reps, xa, atol=1.2)


def test_wire_bytes_measured_from_actual_shapes():
    c = 4
    tree = {"w": jnp.zeros((c, 1024)), "b": jnp.zeros((c, 8))}
    dense = codecs.dense_bytes_per_client(tree)
    assert dense == (1024 + 8) * 4
    enc = codecs.Codec("int8", qblk=128).encode_tree(tree)
    wire = codecs.wire_bytes_per_client(enc)
    # codes: 1032 bytes; scales: (8 + 1) blocks * 4 bytes
    assert wire == 1032 + 9 * 4
    assert dense / wire > 3.5                         # the headline ratio
    # bf16 leaves bill 2 bytes, not the analytic flat 4
    assert codecs.dense_bytes_per_client(
        {"w": jnp.zeros((c, 10), jnp.bfloat16)}) == 20.0


# --------------------------------------------------------- error feedback --
def test_ef_residual_contracts_compression_error():
    """With a FIXED true update u each round, EF makes the decoded sum
    track the true sum: the running-mean error shrinks well below the
    single-shot compression error (the residual telescopes)."""
    c = 4
    u = {"w": jax.random.normal(KEY, (c, 256)) * 0.1}
    codec = codecs.Codec("topk", topk_frac=0.1)
    res = error_feedback.init(u)
    acc = jax.tree_util.tree_map(jnp.zeros_like, u)
    single = None
    for t in range(12):
        enc, dec, res = error_feedback.compress(codec, u, res)
        if t == 0:
            single = float(jnp.abs(dec["w"] - u["w"]).max())
        acc = jax.tree_util.tree_map(lambda a, d: a + d, acc, dec)
        # residual stays bounded (norm of what one round drops)
        assert float(jnp.abs(res["w"]).max()) <= 2.0 * float(
            jnp.abs(u["w"]).max()) * 256
    err = float(jnp.abs(acc["w"] / 12 - u["w"]).max())
    assert err < 0.5 * single, (err, single)


def test_ef_disabled_threads_none():
    u = {"w": jnp.ones((2, 64))}
    enc, dec, res = error_feedback.compress(
        codecs.Codec("int8"), u, None)
    assert res is None


# ------------------------------------------------- fused dequant kernels --
@pytest.mark.parametrize("agg", AGGS)
def test_fused_dequant_bit_exact_vs_decode_then_aggregate(agg):
    """The kernel's in-VMEM dequant replays quant_decode's exact
    q_f32 * scale_f32 multiply, so aggregating the wire codes is
    BIT-IDENTICAL to decoding first and running the dense fused engine
    at the same block size."""
    c = 9
    tree = _tree(c)
    mask = jnp.ones((c,)).at[3].set(0.0)
    w = jax.random.uniform(jax.random.fold_in(KEY, 5), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg, compress="int8")
    codec = codecs.make_codec(cfg)
    enc = codec.encode_tree(tree)
    dec = codec.decode_tree(enc, tree)
    out = jax.jit(lambda e, ww, m: dq.fused_dequant_aggregate_tree(
        e, ww, m, cfg, like=tree, blk=128))(enc, w, mask)
    oracle = fused_aggregate_tree(dec, w, mask, cfg, blk=128)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(oracle[k]), err_msg=k)


@pytest.mark.parametrize("agg", ["trimmed_mean", "median", "krum"])
@pytest.mark.parametrize("c", [8, 9])                 # even + odd C
def test_fused_dequant_within_quant_error_of_dense_oracle(agg, c):
    """Acceptance bound: int8 fused-dequant aggregation within atol 1e-2
    of the dense fp32 multi-pass XLA oracle on the UNCOMPRESSED tree —
    at realistic update scale (local-lr-sized steps; the rank-based
    aggregators pass single coordinates through, so their error is the
    half-quantization-step of that coordinate's block, ~amax/254)."""
    tree = jax.tree_util.tree_map(lambda l: 0.25 * l, _tree(c))
    mask = jnp.ones((c,)).at[2].set(0.0)
    w = jax.random.uniform(jax.random.fold_in(KEY, 6), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg, compress="int8")
    codec = codecs.make_codec(cfg)
    enc = codec.encode_tree(tree)
    out = jax.jit(lambda e, ww, m: dq.fused_dequant_aggregate_tree(
        e, ww, m, cfg, like=tree))(enc, w, mask)
    dense = aggregation.aggregate_ref(tree, w, mask, cfg)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]),
                                   np.asarray(dense[k]), atol=1e-2,
                                   err_msg=k)


@pytest.mark.parametrize("comp", ["int8", "int4", "signsgd", "topk"])
def test_empty_cohort_times_compression_yields_zero(comp):
    """An all-zero participation mask (a NORMAL slotted-protocol state)
    must produce a ZERO update through every codec path — the decoded
    tree through the dense engine AND int8 through the fused dequant
    kernels."""
    c = 6
    tree = _tree(c)
    w = jnp.ones((c,))
    zero_mask = jnp.zeros((c,))
    cfg = FedConfig(n_clients=c, aggregator="trimmed_mean", compress=comp)
    codec = codecs.make_codec(cfg)
    enc = codec.encode_tree(tree)
    dec = codec.decode_tree(enc, tree)
    out = aggregation.aggregate(dec, w, zero_mask, cfg)
    assert all(not np.any(np.asarray(l))
               for l in jax.tree_util.tree_leaves(out))
    if comp == "int8":
        out = jax.jit(lambda e, ww, m: dq.fused_dequant_aggregate_tree(
            e, ww, m, cfg, like=tree))(enc, w, zero_mask)
        assert all(not np.any(np.asarray(l))
                   for l in jax.tree_util.tree_leaves(out))


def test_unfusable_blk_falls_back_to_decode_path():
    """An agg_blk that no qblk tiles (e.g. 1000) must route int8 through
    decode-then-aggregate instead of tripping the kernel's alignment
    assert — and a full round must still run."""
    from repro.core import fedfits

    cfg = FedConfig(n_clients=6, aggregator="trimmed_mean",
                    compress="int8", agg_blk=1000)
    codec = codecs.make_codec(cfg)
    # a leaf WIDER than the pinned blk actually streams at blk=1000,
    # which no 128-wide quant block tiles (leaves narrower than blk get
    # their own 128-aligned width and would still fuse)
    tree = {"w": jax.random.normal(KEY, (6, 4096))}
    assert not dq.should_fuse(codec, cfg, tree)
    assert dq.should_fuse(codec, dataclasses.replace(cfg, agg_blk=None),
                          tree)
    model, fed = _sim(6)
    state, _ = fedfits.run(model, cfg, fed.data_fn, 2,
                           jax.random.PRNGKey(7))
    assert float(state.cost_bytes_up) > 0


def test_fused_dequant_gate_excises_sign_flipped_clients():
    """The cosine outlier gate must keep working ON THE WIRE CODES: int8
    sign-flip poison is excised before the combine."""
    c = 8
    honest = jax.random.normal(KEY, (c, 256)) * 0.01 + 1.0
    upd = {"w": honest.at[0].set(-50.0).at[1].set(-50.0)}
    cfg = FedConfig(n_clients=c, aggregator="median", compress="int8")
    enc = codecs.make_codec(cfg).encode_tree(upd)
    out = jax.jit(lambda e: dq.fused_dequant_aggregate_tree(
        e, jnp.ones((c,)), jnp.ones((c,)), cfg, like=upd))(enc)
    assert np.all(np.asarray(out["w"]) > 0.5)


# ------------------------------------------------------ sharded dequant --
@multidevice
@pytest.mark.parametrize("agg", AGGS)
def test_sharded_fused_dequant_matches_oracle(agg):
    """4-device shard_map fused dequant: codes + scales shard together
    (align=qblk), parity vs decode-then-reference within the shard-local
    summation-order tolerance."""
    from jax.sharding import Mesh

    c = 8
    tree = {"w": jax.random.normal(KEY, (c, 64, 8)),
            "r": jax.random.normal(jax.random.fold_in(KEY, 1), (c, 301)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 2), (c, 5)),
            "h": jax.random.normal(jax.random.fold_in(KEY, 3), (c, 2048))}
    mask = jnp.ones((c,)).at[2].set(0.0)
    w = jax.random.uniform(jax.random.fold_in(KEY, 4), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg, compress="int8")
    codec = codecs.make_codec(cfg)
    enc = codec.encode_tree(tree)
    dec = codec.decode_tree(enc, tree)
    mesh = Mesh(np.array(jax.devices()).reshape(jax.device_count()),
                ("data",))
    out = jax.jit(lambda e, ww, m: dq.fused_dequant_aggregate_sharded(
        e, ww, m, cfg, mesh, like=tree, axes=("data",)))(enc, w, mask)
    ref = aggregation.aggregate_ref(dec, w, mask, cfg)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=1e-5, err_msg=k)


@multidevice
def test_sharded_dequant_scale_alignment_flags():
    """align=qblk: a leaf divisible by the axis extent but NOT by
    extent*qblk must stay replicated (its scale columns cannot shard
    alongside its codes)."""
    from repro.sharding import specs as sh
    from jax.sharding import Mesh

    D = jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(D), ("data",))
    sizes = [2048 * D, 8 * D, 301]
    _, flags = sh.client_flat_specs(sizes, mesh, ("data",), align=128)
    assert flags == (True, False, False)


# ------------------------------------------------ fedfits round wiring --
def _sim(k=6):
    from repro.configs.registry import ARCHS
    from repro.data.pipeline import build_federation
    from repro.models.model import build

    model = build(ARCHS["paper-mlp"])
    fed, test = build_federation(0, kind="tabular", n=600, n_clients=k,
                                 batch_size=16, n_classes=22)
    return model, fed


def test_measured_bytes_accounting_and_unchanged_client_rounds():
    """fedfits.run bills the MEASURED encoded uplink (int8 ~3.9x below
    dense) and the dense downlink, at unchanged cost_client_rounds
    (selection is driven by client-side fitness, untouched by the
    codec)."""
    from repro.core import fedfits

    k = 6
    model, fed = _sim(k)
    outs = {}
    for comp in ["none", "int8"]:
        cfg = FedConfig(n_clients=k, algorithm="fedfits", local_epochs=1,
                        local_lr=0.05, aggregator="trimmed_mean",
                        compress=comp)
        state, _ = fedfits.run(model, cfg, fed.data_fn, 3,
                               jax.random.PRNGKey(7))
        outs[comp] = state
    dense, int8 = outs["none"], outs["int8"]
    assert float(dense.cost_client_rounds) == float(int8.cost_client_rounds)
    assert float(dense.cost_bytes_down) == float(int8.cost_bytes_down) > 0
    ratio = float(dense.cost_bytes_up) / float(int8.cost_bytes_up)
    assert ratio >= 3.5, ratio
    # dense measured == dense itemsize accounting (all-f32 model)
    params = model.init(jax.random.PRNGKey(0))
    p_bytes = sum(l.size * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(params))
    assert float(dense.cost_bytes_up) == \
        float(dense.cost_client_rounds) * p_bytes


def test_scan_driver_bitwise_parity_with_compression():
    """driver="scan" must stay bit-for-bit equal to driver="python" with
    the codec + EF residual threaded through the donated carry."""
    from repro.core import fedfits

    k = 6
    model, fed = _sim(k)
    cfg = FedConfig(n_clients=k, algorithm="fedfits", local_epochs=1,
                    local_lr=0.05, aggregator="trimmed_mean",
                    compress="int8", avail_prob=0.7)
    s_py, h_py = fedfits.run(model, cfg, fed.data_fn, 4,
                             jax.random.PRNGKey(7), driver="python")
    s_sc, h_sc = fedfits.run(model, cfg, fed.data_fn, 4,
                             jax.random.PRNGKey(7), driver="scan",
                             chunk_rounds=3)
    for a, b in zip(jax.tree_util.tree_leaves(s_py),
                    jax.tree_util.tree_leaves(s_sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pod_compress_requires_per_client_boundary():
    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import pod

    with pytest.raises(ValueError):
        pod.make_train_step(ARCHS["tiny-lm"].reduced(),
                            FedConfig(n_clients=4, compress="int8"),
                            TrainConfig(global_batch=8, seq_len=32))


def _pod_run(comp, rounds=4):
    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import pod
    from repro.launch.train import synthetic_lm_batches
    from repro.models import transformer
    from repro.optim import optimizers

    cfgm = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                    n_kv_heads=2, d_ff=128, vocab_size=128,
                                    head_dim=16)
    C, B, S = 4, 8, 32
    fed_cfg = FedConfig(n_clients=C, aggregator="trimmed_mean",
                        compress=comp)
    tc = TrainConfig(global_batch=B, seq_len=S, lr=1e-2, warmup_steps=2,
                     total_steps=rounds)
    params = transformer.init_transformer(jax.random.PRNGKey(0), cfgm)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, C, fed_cfg,
                               jax.random.PRNGKey(0))
    step = pod.make_train_step(cfgm, fed_cfg, tc, robust="per_client")
    sampler = synthetic_lm_batches(cfgm, tc, C, 0)
    skey = jax.random.PRNGKey(123)                # never aliased
    return pod.run(state, step, lambda t: sampler(jax.random.fold_in(
        skey, t)), rounds, driver="scan", chunk_rounds=2)


def test_pod_per_client_compressed_scan_run():
    """The pod engine's codec path end-to-end through the scan driver:
    EF residual rides the donated PodFedState carry across chunks, the
    int8 wire codes feed the fused dequant aggregation, the measured
    comm_bytes_up metric surfaces per round — and the trajectory stays
    within quantization distance of the dense run."""
    state_d, hist_d = _pod_run("none")
    state_c, hist_c = _pod_run("int8")
    assert "comm_bytes_up" not in hist_d[0]
    assert hist_c[0]["comm_bytes_up"] > 0
    assert state_c.fed.ef is not None             # EF survived the carry
    assert any(float(jnp.abs(l).max()) > 0
               for l in jax.tree_util.tree_leaves(state_c.fed.ef))
    for rd, rc in zip(hist_d, hist_c):
        assert np.isfinite(rc["loss"])
        np.testing.assert_allclose(rc["loss"], rd["loss"], atol=5e-2)


def test_topk_ef_reaches_dense_accuracy_on_images():
    """Acceptance: EF-enabled top-k within 1 point of the dense path's
    best accuracy on the synthetic image benchmark (the residual
    re-injects every dropped coordinate within a few rounds)."""
    from benchmarks import common

    model, fed, ev = common.make_setup("images", n_clients=8, n=1200)
    best = {}
    for comp in ["none", "topk"]:
        r = common.run_fl(model, fed, ev, algo="fedfits", rounds=8,
                          n_clients=8, aggregator="trimmed_mean",
                          compress=comp, compress_topk_frac=0.1)
        best[comp] = r["best_acc"]
    assert best["topk"] >= best["none"] - 0.01, best
