"""Unit tests: slotted scheduling state machine (paper Eqs. 4-5)."""
import jax.numpy as jnp
import numpy as np

from repro.core import slots


def run_sequence(thetas, msl, pft, start_t=2):
    st = slots.init_slot_state()
    # seed prev_theta
    st, _ = slots.update(st, jnp.float32(thetas[0]), jnp.int32(start_t),
                         msl, pft)
    out = []
    for i, th in enumerate(thetas[1:], start=start_t + 1):
        st, h = slots.update(st, jnp.float32(th), jnp.int32(i), msl, pft)
        out.append((int(st.p), bool(h)))
    return out


def test_pft_triggers_on_consecutive_decline():
    # theta declines 3 times; pft=2 -> h fires when p reaches 2
    seq = run_sequence([1.0, 0.9, 0.8, 0.7], msl=100, pft=2)
    ps = [p for p, _ in seq]
    hs = [h for _, h in seq]
    assert ps == [1, 2, 3]
    assert hs[1] is True  # p=2 >= pft


def test_counter_resets_on_improvement():
    seq = run_sequence([1.0, 0.9, 1.1, 1.0], msl=100, pft=3)
    ps = [p for p, _ in seq]
    assert ps == [1, 0, 1]


def test_msl_boundary_forces_reselection():
    st = slots.init_slot_state()
    # improving theta so p stays 0; h must still fire when (t+1) % msl == 0
    fired = []
    for t in range(2, 12):
        st, h = slots.update(st, jnp.float32(t), jnp.int32(t), 5, 99)
        fired.append((t, bool(h)))
    assert all(h == (((t + 1) % 5) == 0) for t, h in fired)


def test_round_one_forces_ffa():
    st = slots.init_slot_state()
    _, h = slots.update(st, jnp.float32(0.0), jnp.int32(1), 100, 100)
    assert bool(h) is True


def test_adaptive_slots_stable_team_gets_longer_slots():
    st = slots.init_slot_state()
    # perfectly stable theta: variance -> 0 -> msl_eff -> 2*msl,
    # so (t+1) % msl boundaries inside (msl, 2*msl) do NOT fire
    fires = []
    for t in range(2, 10):
        st, h = slots.update(st, jnp.float32(5.0), jnp.int32(t), 4, 99,
                             adaptive=True)
        fires.append(bool(h))
    assert sum(fires) <= 1
