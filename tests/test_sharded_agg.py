"""Mesh-sharded robust aggregation (aggregation.aggregate_sharded) vs the
replicated oracles on forced multi-device CPU.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=4 (the CI
multi-device job does); on a single-device interpreter these tests skip —
the trivial 1-device mesh path is still covered by the sharded bench
entry in benchmarks/bench_kernels.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core import aggregation

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

KEY = jax.random.PRNGKey(0)
AGGS = ["fedavg", "median", "trimmed_mean", "krum"]


def _mesh(shape, names):
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, names)


def _tree(c):
    """Sharded-path exercise tree: a divisible matrix leaf, a ragged leaf
    (stays replicated), a tiny bias leaf, and a bf16 divisible leaf."""
    return {"w": jax.random.normal(KEY, (c, 64, 8)),
            "r": jax.random.normal(jax.random.fold_in(KEY, 1), (c, 301)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 2), (c, 5)),
            "h": jax.random.normal(jax.random.fold_in(KEY, 3),
                                   (c, 256)).astype(jnp.bfloat16)}


@multidevice
@pytest.mark.parametrize("agg", AGGS)
def test_sharded_matches_ref_all_modes(agg):
    c = 8
    tree = _tree(c)
    mask = jnp.ones((c,)).at[2].set(0.0)
    w = jax.random.uniform(jax.random.fold_in(KEY, 4), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg)
    mesh = _mesh((jax.device_count(),), ("data",))
    out = aggregation.aggregate_sharded(tree, w, mask, cfg, mesh,
                                        axes=("data",))
    ref = aggregation.aggregate_ref(tree, w, mask, cfg)
    for k in ref:
        assert out[k].dtype == tree[k].dtype
        atol = 1e-5 if out[k].dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   atol=atol, err_msg=k)


@multidevice
def test_sharded_2d_mesh_and_pod_axis_excluded():
    """Default axes skip "pod"; a 2D ("data","model") sub-mesh shards the
    flat axis over both."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    c = 8
    tree = _tree(c)
    mask = jnp.ones((c,))
    w = jnp.ones((c,))
    cfg = FedConfig(n_clients=c, aggregator="trimmed_mean")
    mesh = _mesh((2, 2), ("data", "model"))
    out = aggregation.aggregate_sharded(tree, w, mask, cfg, mesh)
    ref = aggregation.aggregate_ref(tree, w, mask, cfg)
    for k in ref:
        atol = 1e-5 if out[k].dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   atol=atol, err_msg=k)


@multidevice
def test_sharded_gate_excises_sign_flipped_clients():
    """The cosine gate must resolve identically when the partials arrive
    via the cross-device psum."""
    c = 8
    honest = jax.random.normal(KEY, (c, 64)) * 0.01 + 1.0
    upd = {"w": honest.at[0].set(-50.0).at[1].set(-50.0)}
    cfg = FedConfig(n_clients=c, aggregator="median")
    mesh = _mesh((jax.device_count(),), ("data",))
    out = aggregation.aggregate_sharded(upd, jnp.ones((c,)), jnp.ones((c,)),
                                        cfg, mesh, axes=("data",))
    assert np.all(np.asarray(out["w"]) > 0.5)


@multidevice
def test_pod_per_client_sharded_matches_replicated():
    """One pod train step with robust='per_client': the mesh-sharded
    aggregation path must reproduce the replicated path's new params."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import pod
    from repro.data import synthetic
    from repro.models import transformer
    from repro.optim import optimizers

    CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab_size=128,
                                   head_dim=16)
    C, B, S = 4, 8, 32
    fed = FedConfig(n_clients=C, aggregator="trimmed_mean")
    tc = TrainConfig(global_batch=B, seq_len=S, total_steps=4,
                     warmup_steps=1)
    params = transformer.init_transformer(KEY, CFG)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, C, fed, KEY)
    toks = synthetic.make_lm_tokens(KEY, B, S + 1, CFG.vocab_size,
                                    n_latent=2)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    mesh = _mesh((jax.device_count(),), ("data",))
    step_rep = jax.jit(pod.make_train_step(CFG, fed, tc,
                                           robust="per_client"))
    step_sh = jax.jit(pod.make_train_step(CFG, fed, tc,
                                          robust="per_client",
                                          agg_mesh=mesh, agg_axes=("data",)))
    s_rep, m_rep = step_rep(state, batch)
    s_sh, m_sh = step_sh(state, batch)
    np.testing.assert_allclose(float(m_rep["loss"]), float(m_sh["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s_rep.params),
                    jax.tree_util.tree_leaves(s_sh.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
