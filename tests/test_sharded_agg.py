"""Mesh-sharded robust aggregation (aggregation.aggregate_sharded) vs the
replicated oracles on forced multi-device CPU.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=4 (the CI
multi-device job does); on a single-device interpreter these tests skip —
the trivial 1-device mesh path is still covered by the sharded bench
entry in benchmarks/bench_kernels.py.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.hlo import parse_collectives
from repro.analysis.traversal import all_eqns
from repro.configs.base import FedConfig
from repro.core import aggregation

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

KEY = jax.random.PRNGKey(0)
AGGS = ["fedavg", "median", "trimmed_mean", "krum"]


def _mesh(shape, names):
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, names)


def _tree(c):
    """Sharded-path exercise tree: a divisible matrix leaf, a ragged leaf
    (stays replicated), a tiny bias leaf, and a bf16 divisible leaf."""
    return {"w": jax.random.normal(KEY, (c, 64, 8)),
            "r": jax.random.normal(jax.random.fold_in(KEY, 1), (c, 301)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 2), (c, 5)),
            "h": jax.random.normal(jax.random.fold_in(KEY, 3),
                                   (c, 256)).astype(jnp.bfloat16)}


@multidevice
@pytest.mark.parametrize("agg", AGGS)
def test_sharded_matches_ref_all_modes(agg):
    c = 8
    tree = _tree(c)
    mask = jnp.ones((c,)).at[2].set(0.0)
    w = jax.random.uniform(jax.random.fold_in(KEY, 4), (c,)) + 0.1
    cfg = FedConfig(n_clients=c, aggregator=agg)
    mesh = _mesh((jax.device_count(),), ("data",))
    out = aggregation.aggregate_sharded(tree, w, mask, cfg, mesh,
                                        axes=("data",))
    ref = aggregation.aggregate_ref(tree, w, mask, cfg)
    for k in ref:
        assert out[k].dtype == tree[k].dtype
        atol = 1e-5 if out[k].dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   atol=atol, err_msg=k)


@multidevice
def test_sharded_2d_mesh_and_pod_axis_excluded():
    """Default axes skip "pod"; a 2D ("data","model") sub-mesh shards the
    flat axis over both."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    c = 8
    tree = _tree(c)
    mask = jnp.ones((c,))
    w = jnp.ones((c,))
    cfg = FedConfig(n_clients=c, aggregator="trimmed_mean")
    mesh = _mesh((2, 2), ("data", "model"))
    out = aggregation.aggregate_sharded(tree, w, mask, cfg, mesh)
    ref = aggregation.aggregate_ref(tree, w, mask, cfg)
    for k in ref:
        atol = 1e-5 if out[k].dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out[k], np.float32),
                                   np.asarray(ref[k], np.float32),
                                   atol=atol, err_msg=k)


@multidevice
def test_sharded_gate_excises_sign_flipped_clients():
    """The cosine gate must resolve identically when the partials arrive
    via the cross-device psum."""
    c = 8
    honest = jax.random.normal(KEY, (c, 64)) * 0.01 + 1.0
    upd = {"w": honest.at[0].set(-50.0).at[1].set(-50.0)}
    cfg = FedConfig(n_clients=c, aggregator="median")
    mesh = _mesh((jax.device_count(),), ("data",))
    out = aggregation.aggregate_sharded(upd, jnp.ones((c,)), jnp.ones((c,)),
                                        cfg, mesh, axes=("data",))
    assert np.all(np.asarray(out["w"]) > 0.5)


@multidevice
def test_no_reshard_between_backward_and_shard_map():
    """ROADMAP open item 2: the per-client vmap'd backward's grad outputs
    are constrained to the ``client_flat_specs`` layout, so the
    ``aggregate_sharded`` shard_map boundary does no reshard.  Guarded at
    two levels: (a) in the jaxpr, every tensor operand of the shard_map
    is produced by a ``sharding_constraint`` whose sharding IS the
    boundary's in_spec — GSPMD therefore has nothing to move; (b) the
    compiled backward->aggregation program contains no all-to-all."""
    from repro.configs.registry import ARCHS
    from repro.data import synthetic
    from repro.models import transformer
    from repro.sharding import specs as sh

    CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab_size=128,
                                   head_dim=16)
    C, B, S = 4, 8, 32
    cfg = FedConfig(n_clients=C, aggregator="trimmed_mean")
    params = transformer.init_transformer(KEY, CFG)
    toks = synthetic.make_lm_tokens(KEY, B, S + 1, CFG.vocab_size,
                                    n_latent=2)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    mesh = _mesh((jax.device_count(),), ("data",))

    def backward_and_agg(params, batch, w, team):
        def client_grad(c):
            bc = B // C

            def one_loss(p):
                sub = {k: jax.lax.dynamic_slice_in_dim(v, c * bc, bc)
                       for k, v in batch.items()}
                return transformer.loss_fn(p, CFG, sub)

            (_, _), g = jax.value_and_grad(one_loss, has_aux=True)(params)
            return g

        grads_c = jax.vmap(client_grad)(jnp.arange(C))
        return aggregation.aggregate_sharded(grads_c, w, team, cfg, mesh,
                                             axes=("data",))

    w = jnp.full((C,), 1.0 / C)
    team = jnp.ones((C,))
    jaxpr = jax.make_jaxpr(backward_and_agg)(params, batch, w, team)

    shard_maps = [(j, e) for j, e in all_eqns(jaxpr)
                  if e.primitive.name == "shard_map"]
    assert len(shard_maps) == 1
    j, eqn = shard_maps[0]
    producers = {id(ov): e2 for e2 in j.eqns for ov in e2.outvars}
    checked = 0
    for iv in eqn.invars:
        shape = getattr(iv.aval, "shape", ())
        if len(shape) != 3:
            continue                     # (C,) weights/mask ride replicated
        prod = producers.get(id(iv))
        assert prod is not None and prod.primitive.name == \
            "sharding_constraint", (shape, prod and prod.primitive.name)
        expected, _ = sh.client_flat_specs([shape[-1]], mesh, ("data",))
        assert prod.params["sharding"].spec == expected[0], shape
        checked += 1
    assert checked >= 4                  # every grad leaf crosses constrained

    txt = jax.jit(backward_and_agg).lower(params, batch, w, team) \
        .compile().as_text()
    assert parse_collectives(txt)["all-to-all"] == 0


@multidevice
def test_pod_run_prefetch_stages_per_shard_and_matches_python():
    """Sharding-aware prefetch (ROADMAP open item 3): pod.run's scan
    driver stages each chunk's batches DIRECTLY onto their pod shards
    (device_put with the lifted NamedSharding), and the sharded-staged
    scan history stays bit-for-bit equal to the python per-round loop fed
    the same shardings."""
    from jax.sharding import PartitionSpec as P

    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import driver, pod
    from repro.launch import inputs
    from repro.launch.train import synthetic_lm_batches
    from repro.models import transformer
    from repro.optim import optimizers

    CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab_size=128,
                                   head_dim=16)
    C, B, S = 4, 8, 32
    mesh = _mesh((jax.device_count(),), ("data",))

    def setup(seed=0):
        key = jax.random.PRNGKey(seed)
        fed = FedConfig(n_clients=C)
        tc = TrainConfig(global_batch=B, seq_len=S, lr=1e-2,
                         warmup_steps=2, total_steps=8)
        params = transformer.init_transformer(key, CFG)
        opt_init, _ = optimizers.make_optimizer(tc)
        state = pod.init_pod_state(params, opt_init, C, fed, key)
        step = pod.make_train_step(CFG, fed, tc)
        sampler = synthetic_lm_batches(CFG, tc, C, seed)
        return key, state, step, sampler

    key, state_sc, step, sampler = setup()
    _, state_py, _, _ = setup()
    sample_key = jnp.array(np.asarray(key))

    def batch_fn(t):
        return sampler(jax.random.fold_in(sample_key, t))

    batch_sh = inputs.batch_shardings(
        jax.eval_shape(sampler, jax.random.PRNGKey(0)), mesh)
    assert batch_sh["tokens"].spec == P(("data",), None)

    # staging lands on the shards, leading chunk dim replicated
    lifted = driver.chunk_sharding(batch_sh)
    _, stacked = driver.stage_chunk(batch_fn, [0, 1, 2], lifted)
    assert stacked["tokens"].shape == (3, B, S)
    assert stacked["tokens"].sharding == lifted["tokens"]
    assert len(stacked["tokens"].sharding.device_set) == jax.device_count()

    s_sc, h_sc = pod.run(state_sc, step, batch_fn, 5, driver="scan",
                         chunk_rounds=2, batch_sharding=batch_sh)
    s_py, h_py = pod.run(state_py, step, batch_fn, 5, driver="python",
                         batch_sharding=batch_sh)
    assert len(h_sc) == len(h_py) == 5
    for r_py, r_sc in zip(h_py, h_sc):
        for k in r_py:
            np.testing.assert_array_equal(
                np.asarray(r_py[k]), np.asarray(r_sc[k]),
                err_msg=f"step {r_py['step']} key {k}")
    for a, b in zip(jax.tree_util.tree_leaves(s_py.params),
                    jax.tree_util.tree_leaves(s_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@multidevice
def test_pod_per_client_sharded_matches_replicated():
    """One pod train step with robust='per_client': the mesh-sharded
    aggregation path must reproduce the replicated path's new params."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import ARCHS
    from repro.core import pod
    from repro.data import synthetic
    from repro.models import transformer
    from repro.optim import optimizers

    CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, d_ff=128, vocab_size=128,
                                   head_dim=16)
    C, B, S = 4, 8, 32
    fed = FedConfig(n_clients=C, aggregator="trimmed_mean")
    tc = TrainConfig(global_batch=B, seq_len=S, total_steps=4,
                     warmup_steps=1)
    params = transformer.init_transformer(KEY, CFG)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, C, fed, KEY)
    toks = synthetic.make_lm_tokens(KEY, B, S + 1, CFG.vocab_size,
                                    n_latent=2)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    mesh = _mesh((jax.device_count(),), ("data",))
    step_rep = jax.jit(pod.make_train_step(CFG, fed, tc,
                                           robust="per_client"))
    step_sh = jax.jit(pod.make_train_step(CFG, fed, tc,
                                          robust="per_client",
                                          agg_mesh=mesh, agg_axes=("data",)))
    s_rep, m_rep = step_rep(state, batch)
    s_sh, m_sh = step_sh(state, batch)
    np.testing.assert_allclose(float(m_rep["loss"]), float(m_sh["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(s_rep.params),
                    jax.tree_util.tree_leaves(s_sh.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
