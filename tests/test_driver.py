"""Shared chunked-scan round driver (core/driver.py) + pod.run: scan vs
python-loop bit-for-bit parity, the donated-carry PRNG aliasing footgun,
and the sharding-aware chunk staging helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig, TrainConfig
from repro.configs.registry import ARCHS
from repro.core import driver, pod
from repro.launch.train import synthetic_lm_batches
from repro.models import transformer
from repro.optim import optimizers

CFG = ARCHS["tiny-lm"].replace(n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab_size=128,
                               head_dim=16)
C, B, S = 4, 8, 32


def _setup(seed=0):
    """train.py-shaped setup: pod state whose PodFedState.rng ALIASES the
    returned key (the donated-carry footgun), plus the jitted sampler."""
    key = jax.random.PRNGKey(seed)
    fed = FedConfig(n_clients=C)
    tc = TrainConfig(global_batch=B, seq_len=S, lr=1e-2, warmup_steps=2,
                     total_steps=10)
    params = transformer.init_transformer(key, CFG)
    opt_init, _ = optimizers.make_optimizer(tc)
    state = pod.init_pod_state(params, opt_init, C, fed, key)
    step = pod.make_train_step(CFG, fed, tc)
    sampler = synthetic_lm_batches(CFG, tc, C, seed)
    return key, state, step, sampler


def _assert_history_equal(h_a, h_b):
    assert len(h_a) == len(h_b)
    for r_a, r_b in zip(h_a, h_b):
        assert set(r_a) == set(r_b)
        for k in r_a:
            np.testing.assert_array_equal(
                np.asarray(r_a[k]), np.asarray(r_b[k]),
                err_msg=f"step {r_a['step']} key {k}")


def test_pod_scan_matches_python_loop_bitwise():
    """pod.run driver="scan" must reproduce the per-round jitted loop
    over make_train_step bit-for-bit — including a ragged tail chunk."""
    key, s_py_state, step, sampler = _setup()
    _, s_sc_state, _, _ = _setup()
    sample_key = jnp.array(np.asarray(key))     # copy: the carry is donated

    def batch_fn(t):
        return sampler(jax.random.fold_in(sample_key, t))

    s_py, h_py = pod.run(s_py_state, step, batch_fn, 7, driver="python")
    s_sc, h_sc = pod.run(s_sc_state, step, batch_fn, 7, driver="scan",
                         chunk_rounds=3)
    _assert_history_equal(h_py, h_sc)
    for a, b in zip(jax.tree_util.tree_leaves(s_py.params),
                    jax.tree_util.tree_leaves(s_sc.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_donated_carry_prng_aliasing_regression():
    """ROADMAP footgun: the scan chunk donates the carry, and the carry
    aliases the init key via PodFedState.rng — the sampler MUST consume
    its key from a copy taken before the first chunk, or the donated
    buffer error bites mid-run.  Guards that (a) sampling from the copy
    keeps the drivers bit-for-bit, (b) when donation is active the
    aliased original is really gone."""
    key, state, step, sampler = _setup(seed=3)
    _, state2, _, _ = _setup(seed=3)
    sample_key = jnp.array(np.asarray(key))     # the REQUIRED live copy

    def batch_fn(t):
        return sampler(jax.random.fold_in(sample_key, t))

    s_sc, h_sc = pod.run(state, step, batch_fn, 6, driver="scan",
                         chunk_rounds=2)
    if key.is_deleted():
        # donation active: the original key's buffer was freed by chunk 0
        # — a sampler holding `key` instead of the copy would crash here
        with pytest.raises((RuntimeError, ValueError)):
            jax.random.fold_in(key, 0).block_until_ready()
    _, h_py = pod.run(state2, step, batch_fn, 6, driver="python")
    _assert_history_equal(h_py, h_sc)


def test_run_chunked_rows_ragged_tail_and_on_chunk():
    """Generic driver contract: n_steps rows labeled by index_key, a
    ragged tail chunk, and the per-chunk callback firing with live
    state."""
    def body(st, xs):
        t, batch = xs
        st = st + batch["x"]
        return st, {"sum": st, "t": t}

    calls = []
    state, hist = driver.run_chunked(
        body, jnp.float32(0.0), lambda t: {"x": jnp.float32(t)}, 5,
        chunk_steps=3, t0=1, index_key="round",
        on_chunk=lambda st, rows: calls.append(len(rows)))
    assert [r["round"] for r in hist] == [1, 2, 3, 4, 5]
    assert calls == [3, 2]                       # full chunk + ragged tail
    np.testing.assert_allclose([r["sum"] for r in hist],
                               np.cumsum([1, 2, 3, 4, 5]))
    assert float(state) == 15.0


def test_chunk_sharding_lifts_leading_dim():
    """The stacked (chunk, ...) batches keep the per-batch sharding with
    a leading replicated chunk dim."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    tree = {"tokens": NamedSharding(mesh, P("data", None)),
            "targets": NamedSharding(mesh, P("data", None))}
    lifted = driver.chunk_sharding(tree)
    assert lifted["tokens"].spec == P(None, "data", None)
    assert lifted["targets"].mesh == mesh


def test_stage_chunk_places_batches_on_sharding():
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    batch_sh = {"x": NamedSharding(mesh, P("data", None))}
    lifted = driver.chunk_sharding(batch_sh)
    ts_dev, stacked = driver.stage_chunk(
        lambda t: {"x": jnp.ones((2, 3)) * t}, [0, 1, 2], lifted)
    assert stacked["x"].shape == (3, 2, 3)
    assert stacked["x"].sharding == lifted["x"]
    np.testing.assert_array_equal(np.asarray(ts_dev), [0, 1, 2])
