"""Unit tests: robust trust-aware aggregation (paper Eq. 11 + Table II)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import aggregation

KEY = jax.random.PRNGKey(0)


def _tree(k=8):
    return {"w": jax.random.normal(KEY, (k, 4, 3)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1), (k, 5))}


def test_weighted_mean_matches_numpy():
    t = _tree()
    w = jnp.array([1, 2, 3, 4, 0, 0, 0, 0], jnp.float32)
    mask = (w > 0).astype(jnp.float32)
    out = aggregation.weighted_mean(t, w, mask)
    wn = np.asarray(w / w.sum())
    ref = np.tensordot(wn, np.asarray(t["w"]), axes=(0, 0))
    assert np.allclose(out["w"], ref, atol=1e-6)


def test_median_masked_matches_numpy():
    t = _tree()
    mask = jnp.array([1, 1, 1, 1, 1, 0, 0, 0], jnp.float32)
    out = aggregation.median(t, mask)
    ref = np.median(np.asarray(t["w"])[:5], axis=0)
    assert np.allclose(out["w"], ref, atol=1e-6)


def test_trimmed_mean_matches_scipy_style():
    t = _tree()
    mask = jnp.ones((8,), jnp.float32)
    out = aggregation.trimmed_mean(t, mask, trim_frac=0.25)
    arr = np.sort(np.asarray(t["w"]), axis=0)[2:-2]
    assert np.allclose(out["w"], arr.mean(0), atol=1e-6)


def test_krum_rejects_outlier():
    k = 8
    base = jax.random.normal(KEY, (k, 10)) * 0.1
    poisoned = base.at[0].set(100.0)
    out = aggregation.krum({"w": poisoned}, jnp.ones((k,)), f=1)
    assert np.abs(np.asarray(out["w"])).max() < 1.0


def test_cosine_outlier_gate():
    k = 6
    upd = jnp.ones((k, 20))
    upd = upd.at[5].set(-1.0)            # sign-flipped client
    ref = jnp.ones((20,))
    gate = aggregation.cosine_outlier_mask({"w": upd}, {"w": ref},
                                           jnp.ones((k,)), thresh=-0.5)
    assert np.array_equal(np.asarray(gate), [1, 1, 1, 1, 1, 0])


def test_aggregate_pipeline_defends_sign_flip():
    k = 8
    honest = jax.random.normal(KEY, (k, 30)) * 0.01 + 1.0
    upd = {"w": honest.at[0].set(-50.0).at[1].set(-50.0)}
    mask = jnp.ones((k,))
    weights = jnp.ones((k,))
    cfg = FedConfig(aggregator="median")
    out = aggregation.aggregate(upd, weights, mask, cfg)
    assert np.all(np.asarray(out["w"]) > 0.5)
    # plain mean without the pipeline is destroyed
    naive = aggregation.weighted_mean(upd, weights, mask)
    assert np.all(np.asarray(naive["w"]) < 0.0)


def test_trust_update_rewards_selected_high_scores():
    trust = jnp.full((4,), 0.5)
    scores = jnp.array([1.0, 0.1, 1.0, 0.1])
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    new = aggregation.update_trust(trust, scores, mask, decay=0.5)
    assert float(new[0]) > float(new[1])        # high score -> more trust
    assert float(new[2]) == float(new[3])       # unselected drift together


def test_two_stage_matches_flat_mean_for_uniform():
    k, n_cohorts = 4, 2
    upd = jax.random.normal(KEY, (n_cohorts, k, 7))
    w = jnp.ones((n_cohorts, k))
    m = jnp.ones((n_cohorts, k))
    cfg = FedConfig(aggregator="fedavg", cosine_outlier_thresh=-1.0)
    out = aggregation.two_stage(upd, w, m, cfg)
    assert np.allclose(out, np.asarray(upd).mean((0, 1)), atol=1e-6)
