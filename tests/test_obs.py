"""Round-trace telemetry subsystem (repro/obs): bitwise on/off parity
across both engines x both drivers (the counter column rides the donated
carry but must never perturb numerics, rng, or billing), counter
correctness against hand-computable engine outcomes, monitor/sink/trace
plumbing, the artifact schema checks, and the bench-merge contract."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import async_engine, fedfits
from repro.core.faults import FaultConfig
from repro.data.pipeline import build_federation
from repro.models.model import build
from repro.obs import (JsonlSink, MemorySink, MultiSink, Telemetry,
                       counters as obs_counters)
from repro.obs.check import check_jsonl, check_trace
from repro.obs.monitors import Monitor, MonitorBank
from repro.obs.sinks import jsonable
from repro.obs.trace import PHASE_NAMES, TraceRecorder

_LATE = FaultConfig(straggler_frac=0.3, straggler_delay=3.0,
                    base_delay=0.3)


def _setup(seed=0, m=12, n=360):
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(seed, kind="tabular", n=n, n_clients=m,
                              batch_size=8, n_classes=10)
    return model, fed


def _sync_cfg(k=6, **kw):
    base = dict(n_clients=k, algorithm="fedfits", local_epochs=1,
                local_lr=0.05, avail_prob=0.7, aggregator="trimmed_mean")
    base.update(kw)
    return FedConfig(**base)


def _async_cfg(c=4, m=12, **kw):
    base = dict(n_clients=c, population=m, algorithm="fedavg",
                aggregator="trimmed_mean", local_epochs=1, local_lr=0.2,
                async_max_retries=2, staleness_decay=0.5)
    base.update(kw)
    return FedConfig(**base)


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)
            if hasattr(l, "shape")]


def _assert_same_history(h_on, h_off):
    """Telemetry adds obs/ keys; every pre-existing key stays bit-equal."""
    assert len(h_on) == len(h_off)
    for r_on, r_off in zip(h_on, h_off):
        assert set(r_off) <= set(r_on)
        assert any(k.startswith("obs/") for k in r_on)
        for k in r_off:
            np.testing.assert_array_equal(
                np.asarray(r_on[k]), np.asarray(r_off[k]), err_msg=k)


# --------------------------------------------------------------------- #
# bitwise on/off parity                                                 #
# --------------------------------------------------------------------- #

@pytest.mark.parametrize("driver", ["python", "scan"])
def test_sync_engine_on_off_bit_parity(driver):
    """Model state, rng, billing, and every pre-existing metric are
    bit-identical with the counter column on vs off."""
    model, fed = _setup(0, m=6, n=240)
    cfg = _sync_cfg()
    kw = dict(driver=driver, chunk_rounds=2)
    st_off, h_off = fedfits.run(model, cfg, fed.data_fn, 4,
                                jax.random.PRNGKey(0), **kw)
    st_on, h_on = fedfits.run(model, cfg, fed.data_fn, 4,
                              jax.random.PRNGKey(0),
                              telemetry=Telemetry(sinks=[MemorySink()]),
                              **kw)
    for a, b in zip(_leaves(st_off.params), _leaves(st_on.params)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(st_off.rng),
                                  np.asarray(st_on.rng))
    assert float(st_off.cost_bytes_up) == float(st_on.cost_bytes_up)
    assert float(st_off.cost_client_rounds) == \
        float(st_on.cost_client_rounds)
    _assert_same_history(h_on, h_off)


@pytest.mark.parametrize("driver", ["python", "scan"])
def test_async_engine_on_off_bit_parity(driver):
    model, fed = _setup(1)
    cfg = _async_cfg()
    kw = dict(driver=driver, chunk_rounds=2, batch_size=8, faults=_LATE,
              straggler_rows="head")
    st_off, h_off = async_engine.run_async(
        model, cfg, fed.data, 4, jax.random.PRNGKey(1), **kw)
    st_on, h_on = async_engine.run_async(
        model, cfg, fed.data, 4, jax.random.PRNGKey(1),
        telemetry=Telemetry(sinks=[MemorySink()]), **kw)
    for a, b in zip(_leaves(st_off.params), _leaves(st_on.params)):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(st_off.rng),
                                  np.asarray(st_on.rng))
    assert float(st_off.cost_client_rounds) == \
        float(st_on.cost_client_rounds)
    _assert_same_history(h_on, h_off)
    # the fault injection actually exercised the buffer counters
    assert sum(r["obs/buffer/parked"] for r in h_on) > 0


def test_async_scan_python_parity_with_telemetry_on():
    """scan==python bit parity holds WITH the counter column riding the
    scan carry — including every obs/ metric."""
    model, fed = _setup(2)
    cfg = _async_cfg()
    kw = dict(batch_size=8, faults=_LATE, straggler_rows="head")
    _, h_p = async_engine.run_async(
        model, cfg, fed.data, 4, jax.random.PRNGKey(2), driver="python",
        telemetry=Telemetry(sinks=[MemorySink()]), **kw)
    _, h_s = async_engine.run_async(
        model, cfg, fed.data, 4, jax.random.PRNGKey(2), driver="scan",
        chunk_rounds=2, telemetry=Telemetry(sinks=[MemorySink()]), **kw)
    for rp, rs in zip(h_p, h_s):
        assert set(rp) == set(rs)
        for k in rp:
            np.testing.assert_array_equal(
                np.asarray(rp[k]), np.asarray(rs[k]), err_msg=k)


# --------------------------------------------------------------------- #
# counter correctness vs engine outcomes                                #
# --------------------------------------------------------------------- #

def test_sync_guard_counters_match_nan_outcome():
    """One NaN-poisoning client: the guard rejects exactly it each round
    and obs/guard/nonfinite bills the same rejection, by kind."""
    model, fed = _setup(3, m=6, n=240)
    # full participation (fedavg, no election) so the poisoner is in
    # every round's team and the per-round count is exactly 1
    cfg = _sync_cfg(algorithm="fedavg", aggregator="fedavg",
                    avail_prob=1.0)
    mal = jnp.zeros((6,)).at[0].set(1.0)

    def nan_attack(upd, malicious, rng):
        return jax.tree_util.tree_map(
            lambda l: jnp.where(
                malicious.reshape((-1,) + (1,) * (l.ndim - 1)) > 0,
                jnp.full_like(l, jnp.nan), l), upd)

    _, hist = fedfits.run(model, cfg, fed.data_fn, 3,
                          jax.random.PRNGKey(3), driver="python",
                          update_attack=nan_attack, malicious=mal,
                          telemetry=Telemetry(sinks=[MemorySink()]))
    for h in hist:
        assert float(h["guard_rejected"]) == 1.0
        assert float(h["obs/guard/nonfinite"]) == 1.0
        assert float(h["obs/guard/norm"]) == 0.0


def test_async_counters_match_buffer_outcomes():
    """Every buffer counter reconciles with the engine's own metrics:
    parked==buffered, occupancy==buf_fill, exhausted+overflow==abandoned,
    guard kinds sum to guard_rejected, on_time is the cohort fraction,
    and the retry-age histogram sums to the live-row count."""
    model, fed = _setup(4)
    cfg = _async_cfg()
    _, hist = async_engine.run_async(
        model, cfg, fed.data, 8, jax.random.PRNGKey(4), driver="python",
        batch_size=8, faults=_LATE, straggler_rows="head",
        telemetry=Telemetry(sinks=[MemorySink()]))
    c = cfg.n_clients
    assert sum(float(h["buffered"]) for h in hist) > 0
    for h in hist:
        assert float(h["obs/buffer/parked"]) == float(h["buffered"])
        assert float(h["obs/buffer/occupancy"]) == float(h["buf_fill"])
        assert (float(h["obs/buffer/exhausted"])
                + float(h["obs/buffer/overflow"])
                == float(h["abandoned"]))
        assert (float(h["obs/guard/nonfinite"])
                + float(h["obs/guard/norm"])
                == float(h["guard_rejected"]))
        np.testing.assert_allclose(
            float(h["obs/delivery/on_time"]),
            float(h["on_time_frac"]) * c, rtol=1e-6)
        assert np.asarray(h["obs/buffer/age_hist"]).sum() == \
            float(h["buf_fill"])


def test_async_exhaustion_counter_totals():
    """One retry, hopeless stragglers (delay >> any backoff window):
    every parked row burns its retry and exhausts, and the abandonment
    counters total exactly the engine's abandoned work and the
    ClientStore failure tally."""
    model, fed = _setup(5, m=16, n=480)
    cfg = _async_cfg(c=4, m=16, async_max_retries=1, async_deadline=0.5)
    fl = FaultConfig(straggler_frac=0.3, straggler_delay=50.0,
                     base_delay=0.01)
    state, hist = async_engine.run_async(
        model, cfg, fed.data, 8, jax.random.PRNGKey(5), driver="python",
        batch_size=8, faults=fl, straggler_rows="head",
        telemetry=Telemetry(sinks=[MemorySink()]))
    exhausted = sum(float(h["obs/buffer/exhausted"]) for h in hist)
    overflow = sum(float(h["obs/buffer/overflow"]) for h in hist)
    assert exhausted > 0                # parked rows time out on retry 1
    abandoned = sum(float(h["abandoned"]) for h in hist)
    assert exhausted + overflow == abandoned
    # clean data -> no guard rejections, so the chronic-failure tally is
    # exactly the abandoned deliveries
    assert abandoned == np.asarray(state.clients.failures).sum()


# --------------------------------------------------------------------- #
# monitors                                                              #
# --------------------------------------------------------------------- #

def test_monitor_k_consecutive_streaks():
    m = Monitor("hot", lambda r: r.get("x"), ">", 0.5, k_consecutive=2)
    fires = [m.observe({"x": v, "round": i}) is not None
             for i, v in enumerate([0.6, 0.4, 0.6, 0.7, 0.7])]
    # a lone trip never fires; the 2nd consecutive (and each after) does
    assert fires == [False, False, False, True, True]
    assert m.observe({"y": 1}) is None          # not-applicable rows skip


def test_monitor_bank_guard_majority_warning():
    bank = MonitorBank()
    row = {"round": 1, "obs/guard/nonfinite": 3.0, "obs/guard/norm": 0.0,
           "obs/select/team_size": 4.0, "obs/gate/cosine_rejected": 0.0,
           "obs/cohort/trust_q": [0.4, 0.5, 0.6]}
    assert bank.observe(row) == []              # streak 1 of 2
    fired = bank.observe({**row, "round": 2})
    assert [w["monitor"] for w in fired] == ["guard_rejecting_majority"]
    assert fired[0]["round"] == 2 and fired[0]["streak"] == 2
    assert bank.counts() == {"guard_rejecting_majority": 1}


# --------------------------------------------------------------------- #
# sinks                                                                 #
# --------------------------------------------------------------------- #

def test_jsonable_coerces_device_scalars():
    assert jsonable(jnp.float32(3.0)) == 3
    assert jsonable(jnp.float32(3.5)) == 3.5
    assert jsonable(np.float64(2**60)) == float(2**60)   # too big for int
    assert jsonable(jnp.arange(3.0)) == [0, 1, 2]
    assert jsonable({"a": (jnp.int32(1), None)}) == {"a": [1, None]}


def test_jsonl_sink_roundtrip_and_close(tmp_path):
    path = str(tmp_path / "m.jsonl")
    s = JsonlSink(path)
    s.emit({"kind": "metrics", "round": 1, "obs/x": jnp.float32(2.0)})
    s.close()
    rows = [json.loads(l) for l in open(path)]
    assert rows == [{"kind": "metrics", "round": 1, "obs/x": 2}]
    with pytest.raises(ValueError):
        s.emit({"kind": "metrics"})


def test_multi_and_memory_sinks_fan_out():
    a, b = MemorySink(), MemorySink(capacity=1)
    multi = MultiSink([a, b])
    multi.emit({"kind": "metrics", "round": 1})
    multi.emit({"kind": "warning", "monitor": "m"})
    assert len(a.records) == 2 and len(b.records) == 1   # ring bounded
    assert a.by_kind("warning") == [{"kind": "warning", "monitor": "m"}]


# --------------------------------------------------------------------- #
# trace + artifact checks                                               #
# --------------------------------------------------------------------- #

def _fake_row(t):
    return {"round": t, "obs/gate/cosine_rejected": 0.0,
            "obs/select/team_size": 4.0}


def test_trace_recorder_emits_checkable_phase_spans(tmp_path):
    rec = TraceRecorder("sync")
    rec.begin("stage")
    rec.end("stage", steps=2)
    rec.emit_rounds(0.0, 1000.0, [_fake_row(1), _fake_row(2)])
    trace = rec.to_json()
    assert trace["displayTimeUnit"] == "ms"
    names = {e["name"] for e in trace["traceEvents"]}
    assert set(PHASE_NAMES) <= names            # >= 5 distinct phases
    assert not check_trace(trace, min_phases=5)
    path = tmp_path / "t.json"
    rec.save(str(path))
    assert not check_trace(str(path), min_phases=5)
    # mutation twin: strip the phase spans -> the check fires
    trace["traceEvents"] = [e for e in trace["traceEvents"]
                            if e["name"] not in PHASE_NAMES]
    assert check_trace(trace, min_phases=5)


def test_run_artifacts_pass_schema_checks(tmp_path):
    """A real scan-driver run: the JSONL stream and the Perfetto trace
    both pass the CI schema checks, with every registered counter
    present and >= 5 distinct phase spans per round."""
    model, fed = _setup(6, m=6, n=240)
    cfg = _sync_cfg()
    jsonl = str(tmp_path / "obs.jsonl")
    tr = str(tmp_path / "trace.json")
    tele = Telemetry(sinks=[JsonlSink(jsonl)], trace_path=tr)
    fedfits.run(model, cfg, fed.data_fn, 3, jax.random.PRNGKey(6),
                driver="scan", chunk_rounds=2, telemetry=tele)
    summary = tele.finish()
    assert summary["rows"] == 3
    assert not check_jsonl(jsonl, require_obs=True, engine="sync")
    assert not check_trace(tr, min_phases=5)
    # mutation twin: a stream with no summary record fails the check
    bad = str(tmp_path / "bad.jsonl")
    with open(jsonl) as f, open(bad, "w") as g:
        g.writelines(l for l in f
                     if json.loads(l).get("kind") != "summary")
    assert check_jsonl(bad, require_obs=True, engine="sync")


# --------------------------------------------------------------------- #
# bench artifact merge contract                                         #
# --------------------------------------------------------------------- #

def test_bench_merge_rows_is_order_independent(tmp_path, monkeypatch):
    """Re-running any bench replaces only its own section: kernel rows
    re-merge by name without dropping the robustness rows, whatever the
    registration order."""
    from benchmarks.common import bench_json_path, merge_rows
    path = str(tmp_path / "BENCH.json")
    monkeypatch.setenv("BENCH_KERNELS_JSON", path)
    assert bench_json_path() == path            # env read at call time
    merge_rows([{"name": "robustness/clean", "acc": 0.9}])
    merge_rows([{"name": "agg/fused", "us": 10.0}])
    merged = merge_rows([{"name": "agg/fused", "us": 12.0}])
    assert merged == json.load(open(path))
    assert {r["name"] for r in merged} == {"robustness/clean",
                                           "agg/fused"}
    assert next(r for r in merged
                if r["name"] == "agg/fused")["us"] == 12.0
