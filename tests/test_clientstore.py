"""Population-scale ClientStore + O(M) Gumbel-top-d selection
(core/clientstore.py, kernels/population_select.py,
sharding.specs.client_store_specs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clientstore as cs
from repro.kernels import population_select as ps


# ----------------------------------------------------------------------
# top-d engine parity: same indices, same (descending-key) order
# ----------------------------------------------------------------------
@pytest.mark.parametrize("m,d,blk", [
    (1000, 8, 128),       # padded tail block
    (1024, 16, 256),      # exact multiple
    (50, 5, 4096),        # single block (blk > m)
    (300, 7, 7),          # blk clamped to d
])
def test_topd_engines_agree(m, d, blk):
    g = jax.random.normal(jax.random.PRNGKey(0), (m,))
    ref = np.asarray(ps.topd_argsort(g, d))
    for method in ("segmented", "pallas"):
        got = np.asarray(ps.topd(g, d, method=method, blk=blk))
        np.testing.assert_array_equal(got, ref, err_msg=method)


def test_topd_degenerate_cohort_covers_population():
    g = jax.random.normal(jax.random.PRNGKey(1), (6,))
    idx = np.asarray(ps.topd(g, 6, method="segmented", blk=4))
    assert sorted(idx.tolist()) == list(range(6))
    np.testing.assert_array_equal(idx, np.argsort(-np.asarray(g)))


def test_topd_duplicate_keys_still_distinct_indices():
    g = jnp.zeros((128,))
    idx = np.asarray(ps.topd(g, 10, method="segmented", blk=32))
    assert len(set(idx.tolist())) == 10


def test_gumbel_topd_proportional_sampling():
    """Efraimidis-Spirakis sanity: inclusion frequency tracks the weight
    ratio (a 10x-weighted client appears far more often in a 2-of-20
    cohort than a 1x one)."""
    w = jnp.ones((20,)).at[3].set(10.0)
    logw = jnp.log(w)
    counts = np.zeros(20)
    for s in range(300):
        idx = np.asarray(ps.gumbel_topd(logw, 2, jax.random.PRNGKey(s)))
        assert len(set(idx.tolist())) == 2      # without replacement
        counts[idx] += 1
    # P(include) = 10/29 + (19/29)(10/28) ~ 0.58 vs ~0.075 for the rest
    others = np.delete(counts, 3)
    assert counts[3] > 140
    assert others.mean() < 40
    assert counts[3] > 4 * others.mean()


def test_gumbel_topd_engine_parity_same_rng():
    """Same rng -> identical cohort across engines (the scan==python and
    engine-swap bit-parity contract)."""
    logw = jnp.log(jax.random.uniform(jax.random.PRNGKey(2), (500,),
                                      minval=0.1))
    r = jax.random.PRNGKey(7)
    a = np.asarray(ps.gumbel_topd(logw, 12, r, method="argsort"))
    b = np.asarray(ps.gumbel_topd(logw, 12, r, method="segmented", blk=64))
    c = np.asarray(ps.gumbel_topd(logw, 12, r, method="pallas", blk=64))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


def test_topd_unknown_method():
    with pytest.raises(ValueError):
        ps.topd(jnp.zeros((8,)), 2, method="quickselect")


# ----------------------------------------------------------------------
# store init / gather / scatter helpers
# ----------------------------------------------------------------------
def test_init_store_shapes_and_priors():
    st = cs.init_store(12)
    assert st.population == 12
    assert st.staleness.dtype == jnp.int32
    assert float(st.trust[0]) == 0.5 and float(st.gate_trust[0]) == 1.0
    assert st.ef is None


def test_gather_pulls_cohort_rows():
    st = cs.init_store(10)
    st = st._replace(fitness=jnp.arange(10.0))
    sub = cs.gather(st, jnp.asarray([7, 2, 9]))
    assert np.asarray(sub.fitness).tolist() == [7.0, 2.0, 9.0]
    assert sub.population == 3


def test_record_selection_and_fitness():
    st = cs.init_store(8)
    idx = jnp.asarray([1, 4])
    st = cs.record_selection(st, idx)
    assert np.asarray(st.cum_selected).tolist() == \
        [0, 1, 0, 0, 1, 0, 0, 0]
    st = cs.record_fitness(st, idx, jnp.asarray([1.0, 0.0]), 0.8)
    np.testing.assert_allclose(np.asarray(st.fitness)[[1, 4]],
                               [0.8 * 0.5 + 0.2, 0.8 * 0.5], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st.fitness)[0], 0.5)


def test_record_deliveries_staleness_semantics():
    st = cs.init_store(6)
    st = cs.record_deliveries(st, jnp.asarray([2, 5]),
                              jnp.asarray([1.0, 0.0]))
    # delivered row resets; everyone else (masked-off row 5 included) ages
    assert np.asarray(st.staleness).tolist() == [1, 1, 0, 1, 1, 1]


def test_record_failures_compounds_duplicates():
    st = cs.init_store(5)
    owners = jnp.asarray([3, 3, 1])
    st = cs.record_failures(st, owners, jnp.asarray([1.0, 1.0, 0.0]),
                            trust_penalty=0.7)
    assert np.asarray(st.failures).tolist() == [0, 0, 0, 2, 0]
    np.testing.assert_allclose(float(st.trust[3]), 0.5 * 0.7 * 0.7,
                               rtol=1e-6)
    np.testing.assert_allclose(float(st.trust[1]), 0.5)   # masked off


def test_record_gate_trust_population_ewma():
    st = cs.init_store(4)
    owners = jnp.asarray([0, 2])
    st = cs.record_gate_trust(st, owners, jnp.asarray([1.0, 1.0]),
                              jnp.asarray([1.0, 0.0]), decay=0.9)
    gt = np.asarray(st.gate_trust)
    np.testing.assert_allclose(gt[0], 0.9)      # gated -> decays
    np.testing.assert_allclose(gt[2], 1.0)      # clean participant holds
    np.testing.assert_allclose(gt[[1, 3]], 1.0)  # non-participants hold


def test_selection_priority_routes_around_flaky_clients():
    """The graceful-degradation routing loop: repeated failures decay
    trust, which shrinks the Gumbel-top-d priority, which shrinks the
    inclusion frequency."""
    st = cs.init_store(16)
    flaky = jnp.asarray([0, 1, 2, 3])
    for _ in range(6):
        st = cs.record_failures(st, flaky, jnp.ones((4,)))
    pri = np.asarray(cs.selection_priority(st))
    assert pri[:4].max() < 0.2 * pri[4:].min()
    counts = np.zeros(16)
    for s in range(200):
        idx = np.asarray(cs.select_cohort(st, 4, jax.random.PRNGKey(s),
                                          blk=8))
        counts[idx] += 1
    assert counts[:4].sum() < 0.25 * counts[4:].sum()
    assert pri.min() >= 1e-12                   # no starvation floor


def test_ef_residuals_allocated_under_compression():
    from repro.configs.base import FedConfig
    params = {"w": jnp.zeros((3, 2)), "b": jnp.zeros((2,))}
    cfg = FedConfig(n_clients=4, compress="int8", error_feedback=True)
    st = cs.init_store(7, params=params, fed_cfg=cfg)
    assert st.ef["w"].shape == (7, 3, 2) and st.ef["b"].shape == (7, 2)


# ----------------------------------------------------------------------
# sharding layout
# ----------------------------------------------------------------------
def test_client_store_specs_population_axis():
    from jax.sharding import PartitionSpec as P

    from repro.sharding import specs as sh
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    st = cs.init_store(8 * n_dev)
    spec = sh.client_store_specs(st, mesh)
    assert spec.fitness == P(("data", "model"))
    assert spec.staleness == P(("data", "model"))
    # a population that does not divide the axes extent replicates
    if n_dev > 1:
        odd = cs.init_store(8 * n_dev + 1)
        assert sh.client_store_specs(odd, mesh).fitness == P(None)
    # sharded store round-trips through device_put
    named = sh.named(mesh, spec)
    placed = jax.device_put(st, named)
    np.testing.assert_array_equal(np.asarray(placed.trust),
                                  np.asarray(st.trust))
