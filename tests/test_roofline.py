"""Unit tests: roofline derivation (HLO collective parsing, term math,
MODEL_FLOPS accounting)."""
import numpy as np

from repro.configs.base import INPUT_SHAPES, HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.configs.registry import ARCHS
from repro.launch import roofline as roof

HLO = """
ENTRY %main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ag = bf16[128,128]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[64,2]{1,0} all-reduce(%x), to_apply=%add
  %a2a = bf16[16,16]{1,0} all-to-all(%y), dimensions={0}
  %agsta = (bf16[32,4]{1,0}, bf16[32,4]{1,0}) all-gather-start(%z)
  %agdone = bf16[32,4]{1,0} all-gather-done(%agsta)
  %cp = u32[10]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not_a_collective = f32[2,2]{1,0} add(%a, %b)
}
"""


def test_parse_collectives():
    out = roof.parse_collectives(HLO)
    assert out["all-gather"] == 128 * 128 * 2 + 2 * 32 * 4 * 2
    assert out["all-reduce"] == 64 * 2 * 4
    assert out["all-to-all"] == 16 * 16 * 2
    assert out["collective-permute"] == 10 * 4
    assert out["reduce-scatter"] == 0


def test_parse_skips_done_halves():
    # '-done' lines are skipped; '-start' counted once
    out = roof.parse_collectives(HLO)
    # only the start tuple contributed (2 x 32 x 4 x 2 bytes)
    assert out["all-gather"] - 128 * 128 * 2 == 512


def test_roofline_terms_and_dominance():
    cost = {"flops": PEAK_FLOPS_BF16, "bytes accessed": HBM_BW / 2}
    coll = {"all-gather": 0, "all-reduce": ICI_BW, "reduce-scatter": 0,
            "all-to-all": 0, "collective-permute": 0}
    r = roof.roofline(cost, coll)
    assert r["compute_s"] == 1.0
    assert r["memory_s"] == 0.5
    assert r["collective_s"] == 2.0        # all-reduce counts 2x
    assert r["dominant"] == "collective_s"
    assert r["bound_s"] == 2.0


def test_model_flops_train_vs_decode():
    cfg = ARCHS["qwen2.5-14b"]
    n = 14_000_000_000
    tr = roof.model_flops(cfg, n, INPUT_SHAPES["train_4k"], "train")
    assert tr == 6.0 * n * 256 * 4096
    de = roof.model_flops(cfg, n, INPUT_SHAPES["decode_32k"], "decode")
    assert de == 2.0 * n * 128


def test_moe_active_params_discount():
    cfg = ARCHS["dbrx-132b"]
    n = 132_000_000_000
    act = roof.active_params(cfg, n)
    expert_w = 40 * 16 * 3 * cfg.d_model * cfg.d_ff
    assert act == n - expert_w + expert_w * 4 // 16
    assert act < n


def test_dense_active_params_identity():
    cfg = ARCHS["qwen2-72b"]
    assert roof.active_params(cfg, 123) == 123
