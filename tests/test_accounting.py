"""Round-level accounting correctness: Algorithm-1 aggregation weights
(convex combination, not the raw-count/|S_t| blow-up) and stale
catch-up billing in ``cost_client_rounds``."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import fedfits
from repro.data.pipeline import build_federation
from repro.models.model import build

K = 6


def _setup(cfg):
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(0, kind="tabular", n=600, n_clients=K,
                              batch_size=16, n_classes=22)
    key = jax.random.PRNGKey(0)
    k_init, k_run = jax.random.split(key)
    params = model.init(k_init)
    state = fedfits.init_state(params, K, cfg, k_run)
    batch = dict(fed.data_fn(1, jax.random.fold_in(key, 1)))
    return model, state, batch


def test_paper_exact_agg_is_convex_combination():
    """The Algorithm-1 literal path must apply a CONVEX combination of
    client updates weighted n_k / sum_{j in S_t} n_j — the old
    n_k/|S_t| normalisation scaled the update by ~mean(n_k) since
    data["n"] carries real partition sizes."""
    cfg = FedConfig(n_clients=K, paper_exact_agg=True, local_epochs=1,
                    local_lr=0.05)
    captured = {}

    def update_attack(updates, mal, rng):      # eager capture, no attack
        captured["u"] = updates
        return updates

    model, state, batch = _setup(cfg)
    round_fn = fedfits.make_round(model, cfg, update_attack=update_attack)
    new_state, metrics = round_fn(state, batch)     # eager: captures live

    team = np.asarray(metrics["team"])
    n = np.asarray(batch["n"], np.float64)
    w = n * team
    w = w / w.sum()
    assert abs(w.sum() - 1.0) < 1e-6 and (w >= 0).all()   # convex weights

    for upd, p_new, p_old in zip(
            jax.tree_util.tree_leaves(captured["u"]),
            jax.tree_util.tree_leaves(new_state.params),
            jax.tree_util.tree_leaves(state.params)):
        upd = np.asarray(upd, np.float64)
        expected = np.tensordot(w, upd, axes=(0, 0))
        got = np.asarray(p_new, np.float64) - np.asarray(p_old, np.float64)
        np.testing.assert_allclose(got, expected, atol=1e-5)
        # and the convexity bound the old /|S_t| formula violated by
        # ~mean(n_k): the aggregate never exceeds the largest update
        assert np.abs(got).max() <= np.abs(upd).max() + 1e-6


def test_stale_clients_billed_in_slot_rounds():
    """Slot rounds must bill the present team PLUS the stale catch-up
    contributors ((stale > 0).sum()) — they trained and submitted an
    update at stale_weight, so their client-round is real work."""
    cfg = FedConfig(n_clients=K, stale_weight=0.3, local_epochs=1,
                    local_lr=0.05)
    model, state, batch = _setup(cfg)
    round_fn = fedfits.make_round(model, cfg)

    team0 = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    avail = jnp.array([1.0, 0.0, 1.0, 1.0, 1.0, 1.0])
    state = state._replace(team=team0, h=jnp.array(False),
                           round=jnp.int32(3))
    batch = dict(batch)
    batch["avail"] = avail

    new_state, metrics = round_fn(state, batch)
    # slot round: team = prior team ∩ available = clients {0, 2};
    # client 1 is a stale catch-up contributor -> billed 2 + 1 = 3
    assert float(metrics["team_size"]) == 2.0
    billed = float(new_state.cost_client_rounds) \
        - float(state.cost_client_rounds)
    assert billed == 3.0


def test_ffa_round_bills_available_plus_stale():
    """FFA (h=True) rounds bill every available client plus the stale
    catch-up contributors — stale updates enter the aggregation in FFA
    rounds too (part = clip(team + stale) is h-independent)."""
    cfg = FedConfig(n_clients=K, stale_weight=0.3, local_epochs=1,
                    local_lr=0.05)
    model, state, batch = _setup(cfg)
    round_fn = fedfits.make_round(model, cfg)
    avail = jnp.array([1.0, 0.0, 1.0, 1.0, 1.0, 1.0])
    state = state._replace(h=jnp.array(True), round=jnp.int32(3))
    batch = dict(batch)
    batch["avail"] = avail
    new_state, _ = round_fn(state, batch)
    billed = float(new_state.cost_client_rounds) \
        - float(state.cost_client_rounds)
    # 5 available + 1 stale contributor (prior-team client 1, unavailable)
    assert billed == 6.0


def test_paper_exact_agg_does_not_bill_stale():
    """paper_exact_agg weighs by n_k * team only — stale updates never
    enter that aggregate, so they must not be billed either."""
    cfg = FedConfig(n_clients=K, paper_exact_agg=True, stale_weight=0.3,
                    local_epochs=1, local_lr=0.05)
    model, state, batch = _setup(cfg)
    round_fn = fedfits.make_round(model, cfg)
    team0 = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    avail = jnp.array([1.0, 0.0, 1.0, 1.0, 1.0, 1.0])
    state = state._replace(team=team0, h=jnp.array(False),
                           round=jnp.int32(3))
    batch = dict(batch)
    batch["avail"] = avail
    new_state, _ = round_fn(state, batch)
    billed = float(new_state.cost_client_rounds) \
        - float(state.cost_client_rounds)
    assert billed == 2.0      # present team only, no stale client-round


def test_no_stale_weight_means_no_stale_billing():
    """With stale_weight=0 (the default) nothing extra is ever billed —
    the paper's original accounting is unchanged."""
    cfg = FedConfig(n_clients=K, local_epochs=1, local_lr=0.05)
    model, state, batch = _setup(cfg)
    round_fn = fedfits.make_round(model, cfg)
    avail = jnp.array([1.0, 0.0, 1.0, 1.0, 1.0, 1.0])
    state = state._replace(h=jnp.array(True), round=jnp.int32(3))
    batch = dict(batch)
    batch["avail"] = avail
    new_state, _ = round_fn(state, batch)
    billed = float(new_state.cost_client_rounds) \
        - float(state.cost_client_rounds)
    assert billed == 5.0
