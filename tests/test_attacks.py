"""Model/data-poisoning attack models (core/attacks.py): seeded
determinism of the stochastic attacks and the honest-rows-untouched
contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks

KEY = jax.random.PRNGKey(0)
K = 6
MAL = jnp.zeros((K,)).at[jnp.arange(2)].set(1.0)


def _updates(key=KEY):
    return {"w": jax.random.normal(key, (K, 17, 3)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 5))}


def test_gaussian_update_seeded_determinism():
    upd = _updates()
    a = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(3))
    b = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(3))
    c = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(4))
    for k in upd:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
        # a different seed draws different noise on the malicious rows
        assert not np.allclose(np.asarray(a[k][:2]), np.asarray(c[k][:2]))


def test_gaussian_update_leaves_honest_rows_untouched():
    upd = _updates()
    out = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(3))
    for k in upd:
        np.testing.assert_array_equal(np.asarray(out[k][2:]),
                                      np.asarray(upd[k][2:]))
        assert not np.allclose(np.asarray(out[k][:2]),
                               np.asarray(upd[k][:2]))


def test_gaussian_update_distinct_noise_per_leaf():
    """Each leaf draws from its own key: the (K, 5) slice of one leaf
    must not reuse another leaf's noise stream."""
    upd = {"x": jnp.zeros((K, 5)), "y": jnp.zeros((K, 5))}
    out = attacks.gaussian_update(upd, jnp.ones((K,)), 1.0,
                                  jax.random.PRNGKey(3))
    assert not np.allclose(np.asarray(out["x"]), np.asarray(out["y"]))


def test_sign_flip_and_scale_attack_deterministic():
    upd = _updates()
    for fn in [lambda u: attacks.sign_flip(u, MAL, scale=3.0),
               lambda u: attacks.scale_attack(u, MAL, 5.0)]:
        a, b = fn(upd), fn(upd)
        for k in upd:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
            np.testing.assert_array_equal(np.asarray(a[k][2:]),
                                          np.asarray(upd[k][2:]))


def test_sign_flip_flips_only_malicious():
    upd = _updates()
    out = attacks.sign_flip(upd, MAL, scale=1.0)
    for k in upd:
        np.testing.assert_allclose(np.asarray(out[k][:2]),
                                   -np.asarray(upd[k][:2]), rtol=1e-6)


def test_label_flip_modes():
    y = jnp.arange(K * 4).reshape(K, 4) % 10
    shift = attacks.label_flip(y, 10, MAL, mode="shift")
    np.testing.assert_array_equal(np.asarray(shift[:2]),
                                  (np.asarray(y[:2]) + 1) % 10)
    np.testing.assert_array_equal(np.asarray(shift[2:]), np.asarray(y[2:]))
    target = attacks.label_flip(y, 10, MAL, mode="target")
    assert np.all(np.asarray(target[:2]) == 0)


def test_feature_noise_seeded_determinism():
    x = jax.random.normal(KEY, (K, 8, 8, 1))
    a = attacks.feature_noise(x, MAL, 0.5, jax.random.PRNGKey(5))
    b = attacks.feature_noise(x, MAL, 0.5, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a[2:]), np.asarray(x[2:]))
