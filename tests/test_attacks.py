"""Model/data-poisoning attack models (core/attacks.py): seeded
determinism of the stochastic attacks and the honest-rows-untouched
contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attacks

KEY = jax.random.PRNGKey(0)
K = 6
MAL = jnp.zeros((K,)).at[jnp.arange(2)].set(1.0)


def _updates(key=KEY):
    return {"w": jax.random.normal(key, (K, 17, 3)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 5))}


def test_gaussian_update_seeded_determinism():
    upd = _updates()
    a = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(3))
    b = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(3))
    c = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(4))
    for k in upd:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
        # a different seed draws different noise on the malicious rows
        assert not np.allclose(np.asarray(a[k][:2]), np.asarray(c[k][:2]))


def test_gaussian_update_leaves_honest_rows_untouched():
    upd = _updates()
    out = attacks.gaussian_update(upd, MAL, 2.0, jax.random.PRNGKey(3))
    for k in upd:
        np.testing.assert_array_equal(np.asarray(out[k][2:]),
                                      np.asarray(upd[k][2:]))
        assert not np.allclose(np.asarray(out[k][:2]),
                               np.asarray(upd[k][:2]))


def test_gaussian_update_distinct_noise_per_leaf():
    """Each leaf draws from its own key: the (K, 5) slice of one leaf
    must not reuse another leaf's noise stream."""
    upd = {"x": jnp.zeros((K, 5)), "y": jnp.zeros((K, 5))}
    out = attacks.gaussian_update(upd, jnp.ones((K,)), 1.0,
                                  jax.random.PRNGKey(3))
    assert not np.allclose(np.asarray(out["x"]), np.asarray(out["y"]))


def test_sign_flip_and_scale_attack_deterministic():
    upd = _updates()
    for fn in [lambda u: attacks.sign_flip(u, MAL, scale=3.0),
               lambda u: attacks.scale_attack(u, MAL, 5.0)]:
        a, b = fn(upd), fn(upd)
        for k in upd:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
            np.testing.assert_array_equal(np.asarray(a[k][2:]),
                                          np.asarray(upd[k][2:]))


def test_sign_flip_flips_only_malicious():
    upd = _updates()
    out = attacks.sign_flip(upd, MAL, scale=1.0)
    for k in upd:
        np.testing.assert_allclose(np.asarray(out[k][:2]),
                                   -np.asarray(upd[k][:2]), rtol=1e-6)


def test_label_flip_modes():
    y = jnp.arange(K * 4).reshape(K, 4) % 10
    shift = attacks.label_flip(y, 10, MAL, mode="shift")
    np.testing.assert_array_equal(np.asarray(shift[:2]),
                                  (np.asarray(y[:2]) + 1) % 10)
    np.testing.assert_array_equal(np.asarray(shift[2:]), np.asarray(y[2:]))
    target = attacks.label_flip(y, 10, MAL, mode="target")
    assert np.all(np.asarray(target[:2]) == 0)


def test_feature_noise_seeded_determinism():
    x = jax.random.normal(KEY, (K, 8, 8, 1))
    a = attacks.feature_noise(x, MAL, 0.5, jax.random.PRNGKey(5))
    b = attacks.feature_noise(x, MAL, 0.5, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a[2:]), np.asarray(x[2:]))


# ------------------------------------------------------------------
# layout-aware backdoor trigger (regression: the old stamp hardcoded
# NHWC and sliced the batch/feature axes of tabular inputs)
# ------------------------------------------------------------------
def test_stamp_trigger_image_layout():
    x = jnp.zeros((K, 4, 6, 6, 2))
    out = attacks.stamp_trigger(x, patch=3, value=1.0)
    assert np.all(np.asarray(out[:, :, :3, :3, :]) == 1.0)
    assert np.all(np.asarray(out[:, :, 3:, :, :]) == 0.0)
    assert np.all(np.asarray(out[:, :, :, 3:, :]) == 0.0)


def test_stamp_trigger_tabular_feature_prefix():
    x = jnp.zeros((K, 5, 9))
    out = attacks.stamp_trigger(x, patch=3, value=1.0)
    assert np.all(np.asarray(out[..., :3]) == 1.0)
    assert np.all(np.asarray(out[..., 3:]) == 0.0)


def test_stamp_trigger_hw_axes_override():
    """Channel-less (B, H, W) would hit the feature-prefix heuristic —
    hw_axes pins the spatial axes explicitly."""
    x = jnp.zeros((5, 8, 8))
    out = attacks.stamp_trigger(x, patch=2, hw_axes=(-2, -1))
    assert np.all(np.asarray(out[:, :2, :2]) == 1.0)
    assert np.all(np.asarray(out[:, 2:, :]) == 0.0)


def test_backdoor_trigger_image_layout():
    x = jax.random.normal(KEY, (K, 4, 6, 6, 1))
    y = jnp.ones((K, 4), jnp.int32) * 5
    bx, by = attacks.backdoor_trigger(x, y, MAL, target=0, patch=2)
    assert np.all(np.asarray(bx[:2, :, :2, :2, :]) == 1.0)
    np.testing.assert_array_equal(np.asarray(bx[2:]), np.asarray(x[2:]))
    assert np.all(np.asarray(by[:2]) == 0)
    np.testing.assert_array_equal(np.asarray(by[2:]), np.asarray(y[2:]))


def test_backdoor_trigger_tabular_layout_regression():
    """(K, B, D) tabular batches: the trigger is a feature prefix — the
    batch axis must NOT be sliced (the old NHWC stamp corrupted the first
    `patch` EXAMPLES of every malicious client instead)."""
    x = jax.random.normal(KEY, (K, 5, 9))
    y = jnp.ones((K, 5), jnp.int32)
    bx, by = attacks.backdoor_trigger(x, y, MAL, target=0, patch=3)
    assert np.all(np.asarray(bx[:2, :, :3]) == 1.0)
    # every malicious EXAMPLE carries the trigger; trailing features and
    # honest clients are untouched
    np.testing.assert_array_equal(np.asarray(bx[:2, :, 3:]),
                                  np.asarray(x[:2, :, 3:]))
    np.testing.assert_array_equal(np.asarray(bx[2:]), np.asarray(x[2:]))
    assert np.all(np.asarray(by[:2]) == 0)


# ------------------------------------------------------------------
# adaptive (optimization-based) attacks
# ------------------------------------------------------------------
def _honest_mu_sd(upd, n_mal=2):
    flat = np.concatenate([np.asarray(l).reshape(K, -1)
                           for l in jax.tree_util.tree_leaves(upd)], axis=1)
    h = flat[n_mal:]
    return flat, h.mean(0), h.std(0)


def test_alie_explicit_z_matches_honest_stats():
    upd = _updates()
    out = attacks.alie(upd, MAL, z=2.0)
    flat, mu, sd = _honest_mu_sd(out)
    np.testing.assert_allclose(flat[0], mu - 2.0 * sd, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(flat[0], flat[1])  # colluders identical


def test_alie_default_z_prescription_finite():
    out = attacks.alie(_updates(), MAL)
    for l in jax.tree_util.tree_leaves(out):
        assert np.all(np.isfinite(np.asarray(l)))


def test_adaptive_attacks_deterministic_and_honest_untouched():
    upd = _updates()
    cfg_like = type("C", (), {"cosine_outlier_thresh": -0.5,
                              "trim_frac": 0.25,
                              "aggregator": "trimmed_mean"})()
    for fn in [lambda u: attacks.alie(u, MAL),
               lambda u: attacks.min_max(u, MAL),
               lambda u: attacks.min_sum(u, MAL),
               lambda u: attacks.gate_aware(u, MAL, cfg_like)]:
        a, b = fn(upd), fn(upd)
        for k in upd:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
            np.testing.assert_array_equal(np.asarray(a[k][2:]),
                                          np.asarray(upd[k][2:]))


def _pairwise_sq(flat):
    d = ((flat[:, None] - flat[None]) ** 2).sum(-1)
    return d


def test_min_max_distance_stays_inside_honest_profile():
    upd = _updates()
    out = attacks.min_max(upd, MAL)
    flat, _, _ = _honest_mu_sd(out)
    d = _pairwise_sq(flat)
    budget = d[2:, 2:].max()            # max honest<->honest distance
    assert d[0, 2:].max() <= budget * (1 + 1e-4)


def test_min_sum_distance_stays_inside_honest_profile():
    upd = _updates()
    out = attacks.min_sum(upd, MAL)
    flat, _, _ = _honest_mu_sd(out)
    d = _pairwise_sq(flat)
    budget = d[2:, 2:].sum(1).max()     # worst honest row-sum
    assert d[0, 2:].sum() <= budget * (1 + 1e-4)


def test_gate_aware_sits_inside_trim_window():
    from repro.configs.base import FedConfig
    key = jax.random.PRNGKey(7)
    k = 10
    mal = jnp.zeros((k,)).at[jnp.arange(3)].set(1.0)
    upd = {"w": jax.random.normal(key, (k, 64)) * 0.1 + 1.0}
    cfg = FedConfig(n_clients=k, aggregator="trimmed_mean", trim_frac=0.2,
                    cosine_outlier_thresh=-0.5)
    out = np.asarray(attacks.gate_aware(upd, mal, cfg)["w"])
    honest = out[3:]
    t = int(np.floor(0.2 * 7))
    s = np.sort(honest, axis=0)
    lo, hi = s[t], s[7 - 1 - t]
    assert np.all(out[0] >= lo - 1e-5) and np.all(out[0] <= hi + 1e-5)
    # and it is adversarial: anti-correlated with the honest mean
    mu = honest.mean(0)
    assert float(out[0] @ mu) < float(mu @ mu)
    # and it clears its own gate: cosine vs the honest median >= thresh
    med = np.median(honest, axis=0)
    cos = (out[0] @ med) / (np.linalg.norm(out[0]) * np.linalg.norm(med))
    assert cos >= cfg.cosine_outlier_thresh - 1e-5


def test_gate_aware_unbounded_against_plain_mean():
    """vs a fedavg aggregator there is no trim window: the crafted update
    is the boosted anti-mean direction, far outside the honest spread."""
    from repro.configs.base import FedConfig
    key = jax.random.PRNGKey(7)
    k = 10
    mal = jnp.zeros((k,)).at[jnp.arange(3)].set(1.0)
    upd = {"w": jax.random.normal(key, (k, 64)) * 0.1 + 1.0}
    cfg = FedConfig(n_clients=k, aggregator="fedavg")
    out = np.asarray(attacks.gate_aware(upd, mal, cfg)["w"])
    assert np.linalg.norm(out[0]) > 5.0 * np.linalg.norm(out[3:], axis=1).max()
