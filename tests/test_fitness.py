"""Unit tests: FedFiTS fitness metrics (paper Eqs. 1-3, 18-19)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness


def test_theta_geometry():
    # pure-loss point lies on the X axis -> angle 0
    th = fitness.theta(jnp.array([1.0]), jnp.array([0.0]),
                       jnp.array([1.0]), jnp.array([0.0]))
    assert np.allclose(th, 0.0, atol=1e-6)
    # pure-accuracy point -> pi/2
    th = fitness.theta(jnp.array([0.0]), jnp.array([1.0]),
                       jnp.array([0.0]), jnp.array([1.0]))
    assert np.allclose(th, np.pi / 2, atol=1e-6)


def test_theta_monotone_in_accuracy():
    gl = jnp.full((5,), 1.0)
    ll = jnp.full((5,), 1.0)
    ga = jnp.linspace(0.1, 0.9, 5)
    la = ga
    th = np.asarray(fitness.theta(gl, ga, ll, la))
    assert np.all(np.diff(th) > 0), "higher accuracy must raise theta"


def test_theta_domain():
    rng = np.random.default_rng(0)
    gl, ll = rng.uniform(0, 10, (2, 100))
    ga, la = rng.uniform(0, 1, (2, 100))
    th = np.asarray(fitness.theta(jnp.asarray(gl), jnp.asarray(ga),
                                  jnp.asarray(ll), jnp.asarray(la)))
    assert np.all(th >= 0) and np.all(th <= np.pi / 2 + 1e-6)


def test_paper_exact_theta_degenerates_at_high_loss():
    """Documents the printed-formula pathology that motivated the fix."""
    gl = ll = jnp.array([6.0])
    ga = la = jnp.array([0.01])
    exact = fitness.theta(gl, ga, ll, la, paper_exact=True)
    fixed = fitness.theta(gl, ga, ll, la)
    assert float(exact[0]) == pytest.approx(0.0, abs=1e-6)
    assert float(fixed[0]) > 0.0


def test_score_and_threshold():
    q = jnp.array([0.5, 0.3, 0.2])
    th = jnp.array([0.2, 0.8, 0.5])
    s = fitness.score(q, th, alpha=0.5)
    assert np.allclose(s, 0.5 * q + 0.5 * th)
    t = fitness.threshold(s, beta=0.1)
    assert np.allclose(t, float(jnp.mean(s)) * 0.9)
    # beta=0 -> threshold is exactly the average line (paper Fig. 1b)
    assert np.allclose(fitness.threshold(s, 0.0), jnp.mean(s))


def test_threshold_respects_mask():
    s = jnp.array([1.0, 1.0, 100.0])
    mask = jnp.array([1.0, 1.0, 0.0])
    assert np.allclose(fitness.threshold(s, 0.0, mask), 1.0)


def test_dynamic_alpha_majority_property():
    """Paper SSV: alpha > 0.5 iff #(q_k > theta_k) > #(q_k < theta_k)."""
    q = jnp.array([0.9, 0.8, 0.7, 0.1])
    th = jnp.array([0.1, 0.1, 0.9, 0.9])
    a = float(fitness.dynamic_alpha(q, th))
    assert a == pytest.approx(0.5)
    q2 = jnp.array([0.9, 0.8, 0.95, 0.1])
    a2 = float(fitness.dynamic_alpha(q2, th))
    assert a2 > 0.5


def test_data_quality_normalised():
    n = jnp.array([10.0, 30.0, 60.0])
    q = fitness.data_quality(n)
    assert np.allclose(q.sum(), 1.0)
    assert np.allclose(q, [0.1, 0.3, 0.6])
