"""Substrate tests: data partitioning, optimizers, checkpointing, sharding
specs, attacks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.core import attacks
from repro.data import partition, synthetic
from repro.optim import optimizers
from repro.sharding import specs as sh

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ data --
def test_dirichlet_partition_covers_everything():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 500)
    parts = partition.dirichlet_partition(rng, labels, 8, alpha=0.3)
    all_idx = np.concatenate(parts)
    assert len(np.unique(all_idx)) >= 490  # tiny clients may duplicate
    # label skew actually happened: clients differ in label histograms
    hists = [np.bincount(labels[p], minlength=10) / max(len(p), 1)
             for p in parts]
    spread = np.std([h.argmax() for h in hists])
    assert spread > 0


def test_stack_clients_shapes_and_sizes():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(300, 5)).astype(np.float32)
    y = rng.integers(0, 4, 300)
    parts = partition.dirichlet_partition(rng, y, 6, alpha=0.5)
    stacked = partition.stack_clients(x, y, parts)
    assert stacked["x"].shape[0] == 6
    assert stacked["x"].shape[2] == 5
    assert stacked["n"].shape == (6,)
    assert (stacked["n"] > 0).all()


def test_synthetic_generators():
    x, y = synthetic.make_images(KEY, 64, n_classes=5)
    assert x.shape == (64, 28, 28, 1) and y.max() < 5
    assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0
    toks = synthetic.make_lm_tokens(KEY, 4, 32, vocab=100)
    assert toks.shape == (4, 32) and int(toks.max()) < 100


# ----------------------------------------------------------------- optim --
def test_adamw_reduces_quadratic():
    tc = TrainConfig(lr=0.1, warmup_steps=1, total_steps=200,
                     optimizer="adamw", weight_decay=0.0)
    init, update = optimizers.make_optimizer(tc)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        upd, state = update(grads, state, params)
        params = optimizers.apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = optimizers.clip_by_global_norm(g, 1.0)
    assert float(optimizers.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_warmup_cosine_schedule():
    lr = optimizers.warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) < 0.2
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, abs=0.05)
    assert float(lr(jnp.int32(99))) < 0.01


# ------------------------------------------------------------ checkpoint --
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": jnp.int32(7)}
    ckpt.save_step(str(tmp_path), 3, tree)
    restored, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 3
    for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                      jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


# -------------------------------------------------------------- sharding --
def test_param_spec_rules():
    from jax.sharding import PartitionSpec as P
    params = {"embed": jnp.zeros((128, 64)),
              "layers": {"b0": {"attn": {"wq": jnp.zeros((2, 64, 64))},
                                "mlp": {"wo": jnp.zeros((2, 128, 64))},
                                "ln1": {"scale": jnp.zeros((2, 64))}}}}
    specs = sh.param_specs(params)
    assert specs["embed"] == P("model", "data")
    assert specs["layers"]["b0"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["b0"]["mlp"]["wo"] == P(None, "model", "data")
    assert specs["layers"]["b0"]["ln1"]["scale"] == P(None, None)


def test_param_spec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"embed": jnp.zeros((33, 7))}   # indivisible by anything > 1
    specs = sh.param_specs(params, mesh=mesh)
    # 1x1 mesh: everything divides, rule applies unchanged
    assert specs["embed"] == P("model", "data")
    assert sh._axis_size(mesh, ("data", "model")) == 1


# --------------------------------------------------------------- attacks --
def test_label_flip_only_hits_malicious():
    y = jnp.zeros((3, 5), jnp.int32)
    mal = jnp.array([1.0, 0.0, 0.0])
    flipped = attacks.label_flip(y, 10, mal)
    assert (np.asarray(flipped[0]) == 1).all()
    assert (np.asarray(flipped[1:]) == 0).all()


def test_sign_flip_and_scale():
    upd = {"w": jnp.ones((2, 3))}
    mal = jnp.array([1.0, 0.0])
    out = attacks.sign_flip(upd, mal, scale=2.0)
    assert (np.asarray(out["w"][0]) == -2.0).all()
    assert (np.asarray(out["w"][1]) == 1.0).all()
    out = attacks.scale_attack(upd, mal, gamma=5.0)
    assert (np.asarray(out["w"][0]) == 5.0).all()


def test_backdoor_trigger():
    x = jnp.zeros((2, 8, 8, 1))
    y = jnp.ones((2, 4), jnp.int32)
    mal = jnp.array([1.0, 0.0])
    xt, yt = attacks.backdoor_trigger(x, y, mal, target=0, patch=2)
    assert float(xt[0, 0, 0, 0]) == 1.0
    assert float(xt[1].max()) == 0.0
    assert (np.asarray(yt[0]) == 0).all()
