"""Fault-injection layer (core/faults.py) + its threading through the
round loop: dropout billing semantics, partial local work, straggler
availability, all-unavailable rounds, and scan-vs-python parity with
faults + adaptive attack + gate-trust EWMA live in the carry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.registry import ARCHS
from repro.core import attacks, faults as faults_mod, fedfits
from repro.core.faults import FaultConfig
from repro.data.pipeline import build_federation
from repro.models.model import build

K = 6


# ------------------------------------------------------------------
# samplers
# ------------------------------------------------------------------
def test_fault_config_active_flags():
    assert not FaultConfig().active
    assert FaultConfig(dropout_prob=0.1).dropout_active
    assert FaultConfig(straggler_frac=0.2).stragglers_active
    assert FaultConfig(base_delay=0.5).stragglers_active
    assert FaultConfig(partial_min_frac=0.5).partial_active


def test_sample_arrivals_chronic_stragglers_are_the_tail():
    fl = FaultConfig(straggler_frac=0.5, straggler_delay=1e6, deadline=1.0)
    a = faults_mod.sample_arrivals(fl, jax.random.PRNGKey(0), 8)
    # fast clients (base_delay=0) always arrive; the slow tail
    # essentially never beats a deadline 1e6x below its mean delay
    np.testing.assert_array_equal(np.asarray(a[:4]), 1.0)
    assert np.asarray(a[4:]).sum() == 0.0


def test_sample_dropout_respects_team_mask():
    fl = FaultConfig(dropout_prob=1.0)
    team = jnp.array([1.0, 0.0, 1.0, 0.0])
    lost = faults_mod.sample_dropout(fl, jax.random.PRNGKey(0), team)
    np.testing.assert_array_equal(np.asarray(lost), [1.0, 0.0, 1.0, 0.0])


def test_sample_epochs_in_range():
    fl = FaultConfig(partial_min_frac=0.25)
    e = np.asarray(faults_mod.sample_epochs(fl, jax.random.PRNGKey(0),
                                            64, 4))
    assert e.min() >= 1 and e.max() <= 4 and len(set(e.tolist())) > 1


# ------------------------------------------------------------------
# round-loop integration
# ------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    model = build(ARCHS["paper-mlp"])
    fed, _ = build_federation(0, kind="tabular", n=600, n_clients=K,
                              batch_size=16, n_classes=10)
    return model, fed


def _round_once(model, fed, cfg, faults=None, batch_extra=None, seed=0):
    round_fn = jax.jit(fedfits.make_round(model, cfg, faults=faults))
    params = model.init(jax.random.PRNGKey(7))
    state = fedfits.init_state(params, K, cfg, jax.random.PRNGKey(8))
    batch = dict(fed.data_fn(1, jax.random.PRNGKey(9)))
    if batch_extra:
        batch.update(batch_extra)
    return state, round_fn(state, batch)


def test_inactive_fault_config_bitwise_equals_none(setup):
    model, fed = setup
    cfg = FedConfig(n_clients=K, local_epochs=2)
    _, (s_none, m_none) = _round_once(model, fed, cfg, faults=None)
    _, (s_off, m_off) = _round_once(model, fed, cfg, faults=FaultConfig())
    for a, b in zip(jax.tree_util.tree_leaves(s_none.params),
                    jax.tree_util.tree_leaves(s_off.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in m_none:
        np.testing.assert_array_equal(np.asarray(m_none[k]),
                                      np.asarray(m_off[k]))


def test_total_dropout_loses_update_but_bills_compute(setup):
    """dropout_prob=1: every selected client computes (billed) but the
    update never lands -> global params unchanged, billing unchanged."""
    model, fed = setup
    cfg = FedConfig(n_clients=K)
    state, (s_drop, m) = _round_once(model, fed, cfg,
                                     faults=FaultConfig(dropout_prob=1.0))
    for a, b in zip(jax.tree_util.tree_leaves(s_drop.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(s_drop.cost_client_rounds) == K      # FFA round bills all
    assert float(s_drop.cost_bytes_up) > 0
    assert float(m["fault_lost"]) == float(m["team_size"])


def test_dropout_does_not_become_stale_catchup(setup):
    """Dropped (selected, computed, lost) is distinct from stale (never
    arrived): with stale_weight on and full dropout the aggregate is
    still zero — dropped clients must not re-enter via the stale path."""
    model, fed = setup
    cfg = FedConfig(n_clients=K, stale_weight=0.5)
    state, (s_drop, _) = _round_once(model, fed, cfg,
                                     faults=FaultConfig(dropout_prob=1.0))
    for a, b in zip(jax.tree_util.tree_leaves(s_drop.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partial_work_changes_update_but_preserves_epoch_count(setup):
    """partial_min_frac < 1 must change the aggregate (fewer effective
    epochs) while the billed client-rounds stay the same — partial work
    is a quality fault, not a billing fault."""
    model, fed = setup
    cfg = FedConfig(n_clients=K, local_epochs=4)
    _, (s_full, m_full) = _round_once(model, fed, cfg, faults=None)
    _, (s_part, m_part) = _round_once(
        model, fed, cfg, faults=FaultConfig(partial_min_frac=0.25))
    assert float(m_part["fault_eff_epochs"]) < 4.0
    assert float(m_full["fault_eff_epochs"]) == 4.0
    assert float(s_part.cost_client_rounds) \
        == float(s_full.cost_client_rounds)
    diffs = [not np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree_util.tree_leaves(s_full.params),
                             jax.tree_util.tree_leaves(s_part.params))]
    assert any(diffs)


def test_stragglers_shrink_the_billed_cohort(setup):
    """Chronic stragglers never beat the deadline -> they are excluded
    from selection AND from billing (they never arrived)."""
    model, fed = setup
    cfg = FedConfig(n_clients=K)
    fl = FaultConfig(straggler_frac=0.5, straggler_delay=1e6)
    _, (s_fault, m) = _round_once(model, fed, cfg, faults=fl)
    assert float(m["team_size"]) <= K // 2
    assert float(s_fault.cost_client_rounds) <= K // 2
    np.testing.assert_array_equal(np.asarray(m["team"][K // 2:]), 0.0)


@pytest.mark.parametrize("algo", ["fedfits", "fedavg", "fedrand", "fedpow"])
def test_no_algorithm_selects_unavailable(algo):
    """Deterministic twin of the hypothesis property (test_property.py
    skips wholesale where hypothesis isn't installed): under any
    availability pattern, team <= avail for every selection algorithm."""
    from repro.core import selection
    for seed in range(20):
        key = jax.random.PRNGKey(seed)
        k = 4 + seed % 9
        avail = (jax.random.uniform(key, (k,)) < 0.5).astype(jnp.float32)
        scores = jax.random.uniform(jax.random.fold_in(key, 1), (k,))
        if algo == "fedfits":
            team = selection.fedfits_select(
                scores, 0.2, avail, jax.random.fold_in(key, 2),
                explore_eps=0.3, floor_prob=0.3)
        elif algo == "fedavg":
            team = selection.fedavg_select(avail)
        elif algo == "fedrand":
            team = selection.fedrand_select(avail, 0.5,
                                            jax.random.fold_in(key, 2))
        else:
            team = selection.fedpow_select(scores, avail, 0.8, 3,
                                           jax.random.fold_in(key, 2))
        bad = np.asarray(team) * (1.0 - np.asarray(avail))
        np.testing.assert_array_equal(bad, 0.0,
                                      err_msg=f"{algo} seed {seed}")


@pytest.mark.parametrize("algo", ["fedfits", "fedavg", "fedrand", "fedpow"])
def test_all_unavailable_round_zero_update_zero_billing(setup, algo):
    model, fed = setup
    cfg = FedConfig(n_clients=K, algorithm=algo)
    state, (s_out, m) = _round_once(
        model, fed, cfg,
        batch_extra={"avail": jnp.zeros((K,), jnp.float32)})
    for a, b in zip(jax.tree_util.tree_leaves(s_out.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(s_out.cost_client_rounds) == 0.0
    assert float(s_out.cost_bytes_up) == 0.0
    assert float(m["team_size"]) == 0.0


def test_scan_python_parity_with_faults_attack_and_trust(setup):
    """The PR invariant: fault draws + gate-trust EWMA live in the scan
    carry, so the chunked scan driver stays bit-for-bit equal to the
    python loop under simultaneous fault injection, an adaptive attack,
    and availability sampling."""
    model, fed = setup
    malicious = jnp.zeros((K,)).at[jnp.arange(2)].set(1.0)

    def update_attack(upd, mal, rng):
        return attacks.alie(upd, mal, z=3.0)

    cfg = FedConfig(n_clients=K, local_epochs=2, avail_prob=0.8,
                    stale_weight=0.3, aggregator="trimmed_mean",
                    trust_in_fitness=True)
    fl = FaultConfig(dropout_prob=0.3, straggler_frac=0.3,
                     straggler_delay=2.0, partial_min_frac=0.5)
    kw = dict(update_attack=update_attack, malicious=malicious, faults=fl)
    s_py, h_py = fedfits.run(model, cfg, fed.data_fn, 5,
                             jax.random.PRNGKey(4), driver="python", **kw)
    s_sc, h_sc = fedfits.run(model, cfg, fed.data_fn, 5,
                             jax.random.PRNGKey(4), driver="scan",
                             chunk_rounds=2, **kw)
    assert len(h_py) == len(h_sc)
    for r_py, r_sc in zip(h_py, h_sc):
        assert set(r_py) == set(r_sc)
        for k in r_py:
            np.testing.assert_array_equal(
                np.asarray(r_py[k]), np.asarray(r_sc[k]),
                err_msg=f"round {r_py['round']} key {k}")
    for a, b in zip(jax.tree_util.tree_leaves(s_py), jax.tree_util.tree_leaves(s_sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
