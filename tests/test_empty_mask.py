"""Empty-cohort correctness: the three-phase protocol (free-for-all ->
natural selection -> slotted teams) makes all-zero participation masks a
NORMAL state — every aggregation path must return a ZERO update for an
empty cohort, never the ``_BIG`` masked-out sentinel that used to leak
through the unclamped median rank index and Krum's all-tied argsort.
Covers the reference, the fused Pallas engine, and ``two_stage`` — plus
fused-vs-ref parity for each case."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import aggregation
from repro.kernels.robust_pipeline import fused_aggregate_tree, \
    fused_two_stage_tree

KEY = jax.random.PRNGKey(0)
AGGS = ["fedavg", "median", "trimmed_mean", "krum"]


def _tree(k=8):
    return {"w": jax.random.normal(KEY, (k, 4, 3)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1), (k, 5))}


def _max_abs(tree):
    return max(float(jnp.abs(l.astype(jnp.float32)).max())
               for l in jax.tree_util.tree_leaves(tree))


def _assert_tree_equal(out, ref, atol=1e-6):
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)


def test_median_empty_mask_returns_zero():
    """The reproduced bug: ``median(u, zeros)`` used to emit the 1e30
    sentinel (rank index -1 wraps to the last = masked sorted entry)."""
    out = aggregation.median(_tree(), jnp.zeros((8,)))
    assert _max_abs(out) == 0.0


@pytest.mark.parametrize("agg", AGGS)
def test_aggregate_empty_mask_zero_both_paths(agg):
    k = 8
    tree = _tree(k)
    w = jnp.ones((k,))
    zeros = jnp.zeros((k,))
    cfg = FedConfig(n_clients=k, aggregator=agg)
    ref = aggregation.aggregate_ref(tree, w, zeros, cfg)
    fused = fused_aggregate_tree(tree, w, zeros, cfg, blk=128)
    assert _max_abs(ref) == 0.0, f"{agg}: sentinel leaked in reference"
    assert _max_abs(fused) == 0.0, f"{agg}: sentinel leaked in fused path"
    _assert_tree_equal(fused, ref)


@pytest.mark.parametrize("agg", AGGS)
def test_aggregate_single_client_mask_both_paths(agg):
    """A lone surviving client's update must pass through unchanged (for
    the masked aggregators) and fused must match ref exactly."""
    k = 8
    tree = _tree(k)
    w = jnp.ones((k,))
    single = jnp.zeros((k,)).at[3].set(1.0)
    cfg = FedConfig(n_clients=k, aggregator=agg)
    ref = aggregation.aggregate_ref(tree, w, single, cfg)
    fused = fused_aggregate_tree(tree, w, single, cfg, blk=128)
    for key in tree:
        np.testing.assert_allclose(np.asarray(ref[key]),
                                   np.asarray(tree[key][3]), atol=1e-6,
                                   err_msg=f"{agg}/{key}")
    _assert_tree_equal(fused, ref)


@pytest.mark.parametrize("agg", AGGS)
def test_two_stage_empty_cohort_row(agg):
    """One empty cohort among live ones: its slot contributes a zero row
    at zero cross-slot weight — no sentinel, fused == ref."""
    g, k = 3, 8
    upd = {"w": jax.random.normal(KEY, (g, k, 33)),
           "b": jax.random.normal(jax.random.fold_in(KEY, 2), (g, k, 5))}
    sw = jnp.ones((g, k))
    sm = jnp.ones((g, k)).at[1].set(0.0)          # cohort 1 empty
    cfg = FedConfig(aggregator=agg)
    ref = aggregation.two_stage_ref(upd, sw, sm, cfg)
    fused = fused_two_stage_tree(upd, sw, sm, cfg, blk=128)
    assert _max_abs(ref) < 1e3, f"{agg}: sentinel leaked through two_stage"
    _assert_tree_equal(fused, ref, atol=1e-5)


@pytest.mark.parametrize("agg", ["median", "trimmed_mean"])
def test_two_stage_all_cohorts_empty(agg):
    g, k = 2, 6
    upd = {"w": jax.random.normal(KEY, (g, k, 33))}
    cfg = FedConfig(aggregator=agg)
    sm = jnp.zeros((g, k))
    ref = aggregation.two_stage_ref(upd, jnp.ones((g, k)), sm, cfg)
    fused = fused_two_stage_tree(upd, jnp.ones((g, k)), sm, cfg, blk=128)
    assert _max_abs(ref) == 0.0
    assert _max_abs(fused) == 0.0


def test_empty_round_keeps_global_model_finite():
    """End-to-end seam: an aggregate over an empty cohort applied to the
    params leaves them unchanged (the straggler/poisoning scenario that
    used to destroy the global model with 1e30s)."""
    k = 8
    tree = _tree(k)
    params = {"w": jnp.ones((4, 3)), "b": jnp.ones((5,))}
    cfg = FedConfig(n_clients=k, aggregator="median")
    agg = aggregation.aggregate(tree, jnp.ones((k,)), jnp.zeros((k,)), cfg)
    new = jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                 params, agg)
    _assert_tree_equal(new, params)
