"""Per-kernel validation: shape/dtype sweeps, Pallas (interpret) vs the
pure-jnp ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention_ops import flash_attention
from repro.kernels.flash_attention_ref import flash_attention_ref
from repro.kernels.robust_agg_ops import (robust_aggregate_tree,
                                          robust_aggregate_tree_ref)

KEY = jax.random.PRNGKey(0)


def _qkv(B, S, Hq, Hkv, dh, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


@pytest.mark.parametrize("S", [128, 256])
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("window", [0, 64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, Hq, Hkv, window, dtype):
    q, k, v = _qkv(2, S, Hq, Hkv, 128, dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=window
    ).transpose(0, 2, 1, 3)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_window_blocks_old_tokens():
    """Row S-1 with window W must equal attention over only last W keys."""
    S, W = 256, 64
    q, k, v = _qkv(1, S, 2, 2, 128, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=W, interpret=True)
    qw = q[:, -1:, :, :]
    ref_probs_in = k[:, S - W:S]
    scores = jnp.einsum("bshd,bthd->bhst",
                        qw * 128 ** -0.5, ref_probs_in)
    probs = jax.nn.softmax(scores, -1)
    ref = jnp.einsum("bhst,bthd->bshd", probs, v[:, S - W:S])
    np.testing.assert_allclose(np.asarray(out[:, -1:]), np.asarray(ref),
                               atol=2e-6)


def test_flash_attention_small_shape_fallback():
    q, k, v = _qkv(1, 32, 2, 2, 64, jnp.float32)   # not tileable -> ref path
    out = flash_attention(q, k, v, causal=True)
    assert out.shape == q.shape and np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("C", [4, 16, 32])
@pytest.mark.parametrize("mode", ["trimmed", "median"])
def test_robust_agg_sweep(C, mode):
    tree = {"a": jax.random.normal(KEY, (C, 13, 7)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 1), (C, 257))}
    mask = jnp.ones((C,)).at[0].set(0.0)
    out = robust_aggregate_tree(tree, mask, mode=mode, trim_frac=0.2,
                                interpret=True)
    ref = robust_aggregate_tree_ref(tree, mask, mode=mode, trim_frac=0.2)
    for kk in tree:
        np.testing.assert_allclose(np.asarray(out[kk]), np.asarray(ref[kk]),
                                   atol=1e-5)


@pytest.mark.parametrize("mode", ["trimmed", "median"])
def test_robust_agg_defends_poison(mode):
    C = 8
    honest = jnp.ones((C, 64)) + 0.01 * jax.random.normal(KEY, (C, 64))
    poisoned = honest.at[0].set(-1e6)
    out = robust_aggregate_tree({"w": poisoned}, jnp.ones((C,)), mode=mode,
                                interpret=True)
    assert np.all(np.asarray(out["w"]) > 0.9)


def test_robust_agg_dtype_bf16_inputs():
    C = 16
    tree = {"w": jax.random.normal(KEY, (C, 384)).astype(jnp.bfloat16)}
    out = robust_aggregate_tree(tree, jnp.ones((C,)), mode="median",
                                interpret=True)
    ref = robust_aggregate_tree_ref(
        {"w": tree["w"].astype(jnp.float32)}, jnp.ones((C,)), mode="median")
    np.testing.assert_allclose(np.asarray(out["w"], np.float32),
                               np.asarray(ref["w"]), atol=1e-2)
