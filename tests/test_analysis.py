"""Both directions of every analysis rule (ISSUE 8 acceptance).

Each rule in repro.analysis.rules must (a) stay silent on a clean
program and (b) fire on a deliberately violating twin. Violations are
small hand-built jaxprs/HLO snippets — mutation fixtures, not the real
entry points (those are covered by the `analysis` CI job running
``python -m repro.analysis.lint --all``).
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo as hlo_mod
from repro.analysis import lint
from repro.analysis import traversal as tv
from repro.analysis.report import EntryResult
from repro.analysis.rules import RULES, RuleContext, run_rules


def _ctx(fn, args, **kw):
    jaxpr = jax.make_jaxpr(fn)(*args)
    res = EntryResult(entry="fixture")
    return RuleContext(entry_name="fixture", jaxpr=jaxpr, result=res, **kw)


def _findings(ctx, rule):
    RULES[rule].fn(ctx)
    return [f for f in ctx.result.findings if f.rule == rule]


# --------------------------------------------------------------------- #
# traversal                                                             #
# --------------------------------------------------------------------- #

def test_all_eqns_recurses_into_scan_bodies():
    def scanny(x):
        def body(c, _):
            c = jnp.concatenate([c, c], axis=-1)[:, :x.shape[-1]]
            return c, ()
        out, _ = jax.lax.scan(body, x, None, length=2)
        return out

    jaxpr = jax.make_jaxpr(scanny)(jnp.ones((4, 8)))
    prims = {e.primitive.name for _, e in tv.all_eqns(jaxpr)}
    assert "scan" in prims and "concatenate" in prims


def test_eqn_provenance_names_user_frame():
    jaxpr = jax.make_jaxpr(lambda x: jnp.concatenate([x, x]))(jnp.ones(4))
    eqn = next(e for _, e in tv.all_eqns(jaxpr)
               if e.primitive.name == "concatenate")
    assert "test_analysis.py:" in tv.eqn_provenance(eqn)


# --------------------------------------------------------------------- #
# copy lint                                                             #
# --------------------------------------------------------------------- #

def test_copy_lint_strict_fires_on_flatten_concat():
    def flatten(tree):
        return jnp.concatenate([l.ravel() for l in tree.values()])

    tree = {"a": jnp.ones((4, 8)), "b": jnp.ones((16,))}
    ctx = _ctx(flatten, (tree,), copy_mode="strict", copy_threshold=16)
    f = _findings(ctx, "copy_lint")
    assert f and "concatenate" in f[0].message
    assert "test_analysis.py" in f[0].provenance


def test_copy_lint_strict_silent_on_leaf_streaming():
    def stream(tree, w):
        return {k: jnp.einsum("c,c...->...", w, v) for k, v in tree.items()}

    tree = {"a": jnp.ones((4, 8)), "b": jnp.ones((4, 16))}
    ctx = _ctx(stream, (tree, jnp.ones(4)), copy_mode="strict",
               copy_threshold=8)
    assert not _findings(ctx, "copy_lint")


def test_copy_lint_engine_allows_leading_axis_row_concat():
    # the async delivery buffer's (rows, ...) stacking is legitimate
    def buffer(rows, stack):
        return jnp.concatenate([rows, stack], axis=0)

    ctx = _ctx(buffer, (jnp.ones((3, 64)), jnp.ones((2, 64))),
               copy_mode="engine", copy_threshold=64)
    assert not _findings(ctx, "copy_lint")


def test_copy_lint_engine_fires_on_minor_axis_concat():
    def glue(a, b):
        return jnp.concatenate([a, b], axis=-1)

    ctx = _ctx(glue, (jnp.ones((3, 64)), jnp.ones((3, 64))),
               copy_mode="engine", copy_threshold=64)
    assert _findings(ctx, "copy_lint")


def test_copy_lint_flags_transpose_fed_reshape_both_modes():
    def relayout(x):
        return x.T.reshape(-1)

    for mode in ("strict", "engine"):
        ctx = _ctx(relayout, (jnp.ones((16, 32)),), copy_mode=mode,
                   copy_threshold=512)
        f = _findings(ctx, "copy_lint")
        assert f and "relayout" in f[0].message

    # a plain reshape (no transpose producer) is a free view
    ctx = _ctx(lambda x: x.reshape(-1), (jnp.ones((16, 32)),),
               copy_mode="strict", copy_threshold=512)
    assert not _findings(ctx, "copy_lint")


# --------------------------------------------------------------------- #
# rng discipline                                                        #
# --------------------------------------------------------------------- #

def test_rng_discipline_fires_on_key_reuse():
    def reuse(key):
        return (jax.random.normal(key, (4,))
                + jax.random.uniform(key, (4,)))

    ctx = _ctx(reuse, (jax.random.PRNGKey(0),))
    f = _findings(ctx, "rng_discipline")
    assert f and "consumed 2x" in f[0].message


def test_rng_discipline_silent_on_split_derivation():
    def clean(key):
        k1, k2 = jax.random.split(key)
        return (jax.random.normal(k1, (4,))
                + jax.random.uniform(k2, (4,)))

    ctx = _ctx(clean, (jax.random.PRNGKey(0),))
    assert not _findings(ctx, "rng_discipline")


def test_rng_discipline_sees_reuse_through_fold_in_chains():
    def clean(key):
        parts = [jax.random.normal(jax.random.fold_in(key, i), (4,))
                 for i in range(3)]
        return sum(parts)

    ctx = _ctx(clean, (jax.random.PRNGKey(0),))
    assert not _findings(ctx, "rng_discipline")

    def dirty(key):
        k = jax.random.fold_in(key, 7)
        return jax.random.normal(k, (4,)) + jax.random.bernoulli(k, 0.5, (4,))

    ctx = _ctx(dirty, (jax.random.PRNGKey(0),))
    assert _findings(ctx, "rng_discipline")


# --------------------------------------------------------------------- #
# rng advance                                                           #
# --------------------------------------------------------------------- #

def test_rng_advance_fires_on_unadvanced_carry():
    def stale(key, x):
        return key, x * 2.0

    ctx = _ctx(stale, (jax.random.PRNGKey(0), jnp.ones(4)),
               check_rng_advance=True)
    f = _findings(ctx, "rng_advance")
    assert f and "unadvanced" in f[0].message


def test_rng_advance_silent_on_advanced_carry():
    def fresh(key, x):
        return jax.random.fold_in(key, 1), x * 2.0

    ctx = _ctx(fresh, (jax.random.PRNGKey(0), jnp.ones(4)),
               check_rng_advance=True)
    assert not _findings(ctx, "rng_advance")


# --------------------------------------------------------------------- #
# donation audit (hlo)                                                  #
# --------------------------------------------------------------------- #

def test_donation_audit_real_alias_and_real_drop():
    x = jnp.ones((128,))

    # in-place carry: XLA aliases param 0
    good = jax.jit(lambda v: v + 1.0, donate_argnums=0) \
        .lower(x).compile().as_text()
    assert 0 in hlo_mod.aliased_param_numbers(good)
    ctx = _ctx(lambda v: v + 1.0, (x,),
               donate_must_alias=((0, ".params"),))
    ctx.hlo_text = good
    assert not _findings(ctx, "donation_audit")

    # shape-shrinking output: the donation is silently dropped
    bad = jax.jit(lambda v: v[:64] * 2.0, donate_argnums=0) \
        .lower(x).compile().as_text()
    assert 0 not in hlo_mod.aliased_param_numbers(bad)
    ctx = _ctx(lambda v: v[:64] * 2.0, (x,),
               donate_must_alias=((0, ".params"),))
    ctx.hlo_text = bad
    f = _findings(ctx, "donation_audit")
    assert f and ".params" in f[0].message


def test_alias_header_parsing():
    txt = ("HloModule jit_step, input_output_alias={ {0}: (0, {}, "
           "may-alias), {2}: (3, {}, must-alias) }, "
           "entry_computation_layout={...}\n")
    entries = hlo_mod.parse_input_output_aliases(txt)
    assert [(e.param_number, e.kind) for e in entries] == \
        [(0, "may-alias"), (3, "must-alias")]
    assert hlo_mod.aliased_param_numbers(txt) == {0, 3}
    assert hlo_mod.parse_input_output_aliases("HloModule bare\n") == []


# --------------------------------------------------------------------- #
# dtype discipline                                                      #
# --------------------------------------------------------------------- #

def test_dtype_discipline_fires_on_half_accumulation():
    # bf16 x bf16 contraction with a bf16 accumulator (jnp.sum would
    # auto-upcast; dot_general keeps the operand dtype)
    def half_mm(a, b):
        return a @ b

    ctx = _ctx(half_mm, (jnp.ones((8, 64), jnp.bfloat16),
                         jnp.ones((64, 256), jnp.bfloat16)),
               copy_threshold=2048)
    f = _findings(ctx, "dtype_discipline")
    assert f and "half-precision accumulation" in f[0].message


def test_dtype_discipline_silent_on_fp32_accum_single_cast():
    def clean(x):
        return jnp.sum(x, axis=0).astype(jnp.bfloat16)

    ctx = _ctx(clean, (jnp.ones((8, 256)),), copy_threshold=256)
    assert not _findings(ctx, "dtype_discipline")


def test_dtype_discipline_fires_on_midchain_round_trips():
    def chatty(x):
        y = x.astype(jnp.bfloat16)          # cast 1
        return (y.astype(jnp.float32) * 2.0).astype(jnp.bfloat16)  # cast 2

    ctx = _ctx(chatty, (jnp.ones((512,)),), copy_threshold=512)
    f = _findings(ctx, "dtype_discipline")
    assert f and "round-trips" in f[0].message


# --------------------------------------------------------------------- #
# pallas budget                                                         #
# --------------------------------------------------------------------- #

def _pallas_fn(shape, block, grid):
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        in_specs=[pl.BlockSpec(block, lambda i: (i, 0))],
        out_specs=pl.BlockSpec(block, lambda i: (i, 0)),
        grid=grid)


def test_pallas_budget_notes_small_kernel():
    fn = _pallas_fn((32, 128), (8, 128), (4,))
    ctx = _ctx(fn, (jax.ShapeDtypeStruct((32, 128), jnp.float32),))
    assert not _findings(ctx, "pallas_budget")
    assert any("vmem~" in n for n in ctx.result.notes)


def test_pallas_budget_fires_on_oversized_blocks():
    # 2 x (in + out) x 8 MiB blocks = 32 MiB >> the 12 MiB budget
    fn = _pallas_fn((4096, 1024), (2048, 1024), (2,))
    ctx = _ctx(fn, (jax.ShapeDtypeStruct((4096, 1024), jnp.float32),))
    f = _findings(ctx, "pallas_budget")
    assert f and "exceeds" in f[0].message


def test_pallas_budget_reports_lane_minor_blocks():
    fn = _pallas_fn((32, 8), (8, 8), (4,))
    ctx = _ctx(fn, (jax.ShapeDtypeStruct((32, 8), jnp.float32),))
    assert not _findings(ctx, "pallas_budget")
    assert any("lane-minor" in n for n in ctx.result.notes)


# --------------------------------------------------------------------- #
# fusion count (hlo)                                                    #
# --------------------------------------------------------------------- #

# fused aggregation: one cohort-axis sort (1x payload) + the 1/C-sized
# aggregated output — ~1.1 passes over a 1024B payload
_HLO_FUSED = """\
HloModule fused_agg
%fused_computation { ... }
ENTRY %main.9 (Arg_0.1: f32[8,32]) -> f32[32] {
  %Arg_0.1 = f32[8,32]{1,0} parameter(0)
  %sort.1 = f32[8,32]{1,0} sort(f32[8,32]{1,0} %Arg_0.1), dimensions={0}
  ROOT %fusion.1 = f32[32]{0} fusion(f32[8,32]{1,0} %sort.1), kind=kLoop, calls=%fused_computation
}
"""

# mutation twin: XLA dropped the fusion — the payload is re-sorted,
# copied, and flattened through fresh cohort-sized buffers (>4 passes)
_HLO_SPILLED = """\
HloModule spilled_agg
ENTRY %main.9 (Arg_0.1: f32[8,32]) -> f32[32] {
  %Arg_0.1 = f32[8,32]{1,0} parameter(0)
  %sort.1 = f32[8,32]{1,0} sort(f32[8,32]{1,0} %Arg_0.1), dimensions={0}
  %copy.1 = f32[8,32]{1,0} copy(f32[8,32]{1,0} %sort.1)
  %sort.2 = f32[8,32]{1,0} sort(f32[8,32]{1,0} %copy.1), dimensions={0}
  %reshape.1 = f32[256]{0} reshape(f32[8,32]{1,0} %sort.2)
  %tuple.5 = (f32[256]{0}) tuple(f32[256]{0} %reshape.1)
  %gte.1 = f32[256]{0} get-tuple-element((f32[256]{0}) %tuple.5), index=0
  ROOT %reduce.1 = f32[32]{0} reduce(f32[256]{0} %gte.1, f32[] %c), dimensions={0}
}
"""


def _fusion_ctx(text, cap):
    ctx = _ctx(lambda x: x, (jnp.ones(1),), hbm_pass_cap=cap,
               hbm_payload_bytes=8 * 32 * 4, hbm_bytes_threshold=128)
    ctx.hlo_text = text
    return ctx


def test_iter_materializations_entry_only_and_exempt():
    mats = list(hlo_mod.iter_materializations(_HLO_SPILLED, min_bytes=128))
    ops = [m.op for m in mats]
    # parameter/tuple/get-tuple-element are exempt; everything else counts
    assert ops == ["sort", "copy", "sort", "reshape", "reduce"]
    assert mats[0].bytes == 8 * 32 * 4
    # sub-computation bodies outside ENTRY are invisible
    assert not list(hlo_mod.iter_materializations(
        "%fused { %a = f32[999]{0} add(...) }\n"))


def test_fusion_count_silent_on_fused_aggregation():
    ctx = _fusion_ctx(_HLO_FUSED, cap=2.0)
    assert not _findings(ctx, "fusion_count")
    assert any("hbm passes" in n for n in ctx.result.notes)


def test_fusion_count_fires_on_spilled_chain():
    ctx = _fusion_ctx(_HLO_SPILLED, cap=2.0)
    f = _findings(ctx, "fusion_count")
    assert f and "spilling intermediates" in f[0].message
    assert "sort" in f[0].message


def test_fusion_count_noop_without_cap():
    ctx = _ctx(lambda x: x, (jnp.ones(1),))
    ctx.hlo_text = _HLO_SPILLED
    assert not _findings(ctx, "fusion_count")


# --------------------------------------------------------------------- #
# collective lint (hlo)                                                 #
# --------------------------------------------------------------------- #

_HLO_ALLREDUCE = """\
HloModule sharded
  %p = f32[8]{0} parameter(0)
  %r = f32[8]{0} all-reduce(f32[8]{0} %p), replica_groups={}
"""
_HLO_ALLGATHER = """\
HloModule sharded
  %p = f32[256,256]{1,0} parameter(0)
  %g = f32[1024,256]{1,0} all-gather(f32[256,256]{1,0} %p), dimensions={0}
"""


def _hlo_ctx(text, allowlist):
    ctx = _ctx(lambda x: x, (jnp.ones(1),),
               collective_allowlist=allowlist)
    ctx.hlo_text = text
    return ctx


def test_collective_lint_silent_under_cap():
    ctx = _hlo_ctx(_HLO_ALLREDUCE, {"all-reduce": 1024})
    assert not _findings(ctx, "collective_lint")
    assert any("all-reduce=32B" in n for n in ctx.result.notes)


def test_collective_lint_fires_on_forbidden_kind():
    ctx = _hlo_ctx(_HLO_ALLGATHER, {"all-reduce": 1024})
    f = _findings(ctx, "collective_lint")
    assert f and "forbidden collective all-gather" in f[0].message


def test_collective_lint_fires_over_cap():
    ctx = _hlo_ctx(_HLO_ALLREDUCE, {"all-reduce": 8})
    f = _findings(ctx, "collective_lint")
    assert f and "caps it at 8" in f[0].message


def test_collectives_parser_skips_done_halves():
    txt = ("  %s = f32[64]{0} all-gather-start(f32[16]{0} %p)\n"
           "  %d = f32[64]{0} all-gather-done(f32[64]{0} %s)\n")
    out = hlo_mod.parse_collectives(txt)
    assert out["all-gather"] == 64 * 4      # start counted once


# --------------------------------------------------------------------- #
# CLI / report plumbing                                                 #
# --------------------------------------------------------------------- #

def test_lint_cli_clean_entry_and_json_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = lint.main(["--entry", "aggregate", "--json", str(out)])
    assert rc == 0
    assert "ok   aggregate" in capsys.readouterr().out
    data = json.loads(out.read_text())
    assert data["summary"]["errors"] == 0
    assert data["results"][0]["entry"] == "aggregate"


def test_lint_cli_list_and_unknown_entry(capsys):
    assert lint.main(["--list"]) == 0
    assert "aggregate_sharded" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        lint.main(["--entry", "no_such_entry"])


def test_run_rules_sets_findings_status():
    ctx = _ctx(lambda a, b: jnp.concatenate([a, b], -1),
               (jnp.ones((2, 8)), jnp.ones((2, 8))),
               copy_mode="engine", copy_threshold=8)
    res = run_rules(ctx)
    assert res.status == "findings"
    assert all(f.rule == "copy_lint" for f in res.findings)
